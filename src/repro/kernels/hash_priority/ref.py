"""Pure-jnp oracle for the hash+pack kernel (== core.hashing/tuples path)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.hashing import priorities_xorshift_star
from ...core.tuples import pack


def hash_pack_ref(iteration, vertex_ids: jnp.ndarray, b: int) -> jnp.ndarray:
    return pack(priorities_xorshift_star(iteration, vertex_ids), vertex_ids, b)
