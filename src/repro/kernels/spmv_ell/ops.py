"""Jitted wrapper for the Pallas ELL SpMV.

``interpret=None`` defers to the :class:`repro.api.Backend` policy
(interpret only off-accelerator) instead of the seed's hard ``True``.
"""
from __future__ import annotations

from ...graphs.csr import ELLMatrix
from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import spmv_ell_pallas


def spmv(m: ELLMatrix, x, *, interpret: bool | None = None):
    return spmv_ell_pallas(m.cols, m.vals, x,
                           interpret=_resolve_interpret(interpret))
