"""Jitted wrapper for the Pallas ELL SpMV."""
from __future__ import annotations

from ...graphs.csr import ELLMatrix
from .kernel import spmv_ell_pallas


def spmv(m: ELLMatrix, x, *, interpret: bool = True):
    return spmv_ell_pallas(m.cols, m.vals, x, interpret=interpret)
