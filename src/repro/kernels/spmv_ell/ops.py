"""Jitted wrappers for the Pallas ELL SpMV kernels.

``interpret=None`` defers to the :class:`repro.api.Backend` policy
(interpret only off-accelerator) instead of the seed's hard ``True``.
"""
from __future__ import annotations

from ...graphs.csr import ELLMatrix
from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import spmv_ell_pallas, spmv_ell_t_pallas


def spmv(m: ELLMatrix, x, *, interpret: bool | None = None):
    return spmv_ell_pallas(m.cols, m.vals, x,
                           interpret=_resolve_interpret(interpret))


def spmv_t(m: ELLMatrix, x, num_out: int, *, interpret: bool | None = None):
    """y = M^T @ x for rectangular ELL M ([rows, num_out] logically) —
    the matrix-free restriction op (R = P^T) of the multilevel solve."""
    return spmv_ell_t_pallas(m.cols, m.vals, x, num_out=num_out,
                             interpret=_resolve_interpret(interpret))
