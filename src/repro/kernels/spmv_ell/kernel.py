"""Pallas TPU ELL SpMV — the AMG smoother / Gauss-Seidel hot loop.

Same tiling story as minprop_ell: a ``[BLOCK_ROWS, D]`` tile of (cols, vals)
per grid step, ``x`` VMEM-resident, 1-D vector gather + fused
multiply-reduce on the VPU, fp32 accumulation.  Padding slots carry
``val == 0`` so no mask load is needed — the ELL format itself encodes
the paper's "no divergence" property.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                        # [B, D] int32
    vals = vals_ref[...]                        # [B, D] f32
    x = x_ref[...]                              # [V]  (VMEM-resident)
    xg = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    y_ref[...] = jnp.sum(vals.astype(jnp.float32) * xg.astype(jnp.float32),
                         axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def spmv_ell_pallas(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, *,
                    interpret: bool = True,
                    block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    v, d = cols.shape
    block = min(block_rows, v)
    grid = pl.cdiv(v, block)
    return pl.pallas_call(
        _spmv_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), x.dtype),
        interpret=interpret,
    )(cols, vals, x)
