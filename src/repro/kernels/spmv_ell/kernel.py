"""Pallas TPU ELL SpMV — the AMG smoother / Gauss-Seidel hot loop.

Same tiling story as minprop_ell: a ``[BLOCK_ROWS, D]`` tile of (cols, vals)
per grid step, ``x`` VMEM-resident, 1-D vector gather + fused
multiply-reduce on the VPU, fp32 accumulation.  Padding slots carry
``val == 0`` so no mask load is needed — the ELL format itself encodes
the paper's "no divergence" property.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref, *, num_rows: int):
    """Rows past ``num_rows`` (the ragged final block) gather from col 0
    with zero weight — compiled Pallas pads partial blocks with
    unspecified values, so an unmasked ``jnp.take`` would read out of
    bounds on hardware while interpret mode (zero padding) stays green."""
    i = pl.program_id(0)
    block = cols_ref.shape[0]
    valid = i * block + jnp.arange(block) < num_rows
    cols = jnp.where(valid[:, None], cols_ref[...], 0)      # [B, D] int32
    vals = jnp.where(valid[:, None],
                     vals_ref[...].astype(jnp.float32), 0.0)  # [B, D] f32
    x = x_ref[...]                              # [V]  (VMEM-resident)
    xg = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    y_ref[...] = jnp.sum(vals * xg.astype(jnp.float32),
                         axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def spmv_ell_pallas(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, *,
                    interpret: bool = True,
                    block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    v, d = cols.shape
    block = min(block_rows, v)
    grid = pl.cdiv(v, block)
    return pl.pallas_call(
        functools.partial(_spmv_kernel, num_rows=v),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), x.dtype),
        interpret=interpret,
    )(cols, vals, x)


def _spmv_t_kernel(cols_ref, vals_ref, x_ref, y_ref, *, num_rows: int):
    """Transposed SpMV grid step: scatter one row block into the full
    (VMEM-resident) output, accumulating across grid steps.  Rows past
    ``num_rows`` (the ragged final block) are masked to zero — compiled
    Pallas pads partial blocks with unspecified values, unlike interpret
    mode's zero padding."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    block = cols_ref.shape[0]
    valid = i * block + jnp.arange(block) < num_rows
    cols = jnp.where(valid[:, None], cols_ref[...], 0)     # [B, D] int32
    vals = vals_ref[...]                                   # [B, D] f32
    x = x_ref[...]                                         # [B]
    contrib = jnp.where(valid[:, None],
                        vals.astype(jnp.float32)
                        * x.astype(jnp.float32)[:, None], 0.0)
    y = y_ref[...]
    y_ref[...] = y.at[cols.reshape(-1)].add(
        contrib.reshape(-1).astype(y.dtype))


@functools.partial(jax.jit, static_argnames=("num_out", "interpret",
                                             "block_rows"))
def spmv_ell_t_pallas(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
                      *, num_out: int, interpret: bool = True,
                      block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """y = A^T @ x for rectangular ELL A (restriction without an explicit
    R matrix).  The output vector stays resident in VMEM across the whole
    grid; each step scatters one ``[BLOCK_ROWS, D]`` tile into it."""
    v, d = cols.shape
    block = min(block_rows, v)
    grid = pl.cdiv(v, block)
    return pl.pallas_call(
        functools.partial(_spmv_t_kernel, num_rows=v),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_out,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_out,), x.dtype),
        interpret=interpret,
    )(cols, vals, x)
