"""Pure-jnp oracle for the ELL SpMV kernel."""
from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(cols: jnp.ndarray, vals: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x, A in ELL (padding: col=row, val=0)."""
    return jnp.sum(vals * x[cols], axis=1)


def spmv_ell_t_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
                   num_out: int) -> jnp.ndarray:
    """y = A^T @ x with A in (possibly rectangular) ELL form.

    ``A`` is ``[rows, num_out]`` logically; ``x`` has length ``rows`` and
    the scatter accumulates ``vals[r, j] * x[r]`` into ``cols[r, j]``.
    Padding carries ``val == 0`` so it contributes nothing.
    """
    contrib = vals * x[:, None]                  # [rows, D]
    return jnp.zeros(num_out, x.dtype).at[cols].add(contrib.astype(x.dtype))
