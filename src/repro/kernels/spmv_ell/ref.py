"""Pure-jnp oracle for the ELL SpMV kernel."""
from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(cols: jnp.ndarray, vals: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x, A in ELL (padding: col=row, val=0)."""
    return jnp.sum(vals * x[cols], axis=1)
