# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
from .config import (
    LM_SHAPES,
    ModelConfig,
    ShapeCell,
    cell_applicable,
)
from .griffin import GriffinLM
from .mamba2 import Mamba2LM
from .transformer import TransformerLM
from .whisper import WhisperModel


def get_model(cfg: ModelConfig):
    """Model registry keyed by family."""
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "ssm":
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        return GriffinLM(cfg)
    if cfg.family in ("encdec", "audio"):
        return WhisperModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")


__all__ = ["LM_SHAPES", "ModelConfig", "ShapeCell", "cell_applicable",
           "GriffinLM", "Mamba2LM", "TransformerLM", "WhisperModel",
           "get_model"]
