# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""Model configuration covering all ten assigned architectures.

Every architecture is a ``ModelConfig``; family-specific fields are unused
elsewhere.  ``src/repro/configs/<arch>.py`` builds the exact assigned
configs; reduced smoke variants come from ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    moe_capacity_factor: float = 1.25
    norm_topk_prob: bool = True

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # --- hybrid (recurrentgemma / griffin) ---
    block_pattern: tuple = ()        # e.g. ("rglru", "rglru", "local")
    local_window: int = 2048
    d_rnn: int = 0                   # RG-LRU width (0 -> d_model)
    rglru_c: float = 8.0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30s @ 50 Hz after conv stub

    # --- common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False
    act_dtype: str = "bfloat16"
    remat: str = "layer"             # none | layer | dots
    attention_block_q: int = 512     # flash attention tiles
    attention_block_kv: int = 1024
    notes: str = ""

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so TP sharding over 16|32 ways divides evenly."""
        return pad_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        base = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_multiple=64,
            head_dim=32 if self.num_heads else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            local_window=32 if self.block_pattern else self.local_window,
            d_rnn=128 if self.d_rnn else 0,
            ssm_state=32 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 256,
            num_experts=min(self.num_experts, 4),
            num_experts_per_token=min(self.num_experts_per_token, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=64 if self.moe_d_ff else 0,
            attention_block_q=16,
            attention_block_kv=32,
            name=self.name + "-reduced",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    shape_name: str       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str             # train | prefill | decode


LM_SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

# families that can hold 500k tokens of state (sub-quadratic decode);
# pure full-attention archs skip long_500k (DESIGN.md §6)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    if cell.shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full-attention KV cache at 512k tokens/seq is "
                       "unservable; skipped per assignment (sub-quadratic "
                       "archs only)")
    return True, ""
