# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings ``[B, encoder_seq, d]`` directly to the
encoder (the 2xConv1d stem would add <1% of FLOPs).  The transformer
backbone is faithful: pre-LayerNorm blocks, GELU MLPs, full bidirectional
encoder attention, causal decoder self-attention + cross-attention,
sinusoidal positions.  kv_heads == num_heads (MHA) for whisper-tiny.

Serving: decoder self-KV cache + cross-KV precomputed once at prefill.
Decode shapes exercise the decoder with a 32k cache — a dry-run shape
beyond Whisper's trained 448 positions, stated as such in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (constrain, decode_attention, dense_init, embed_init,
                     embed_lookup, flash_attention)

Params = Dict[str, Any]


def layer_norm(x, weight, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(dt)


def sinusoid_positions(seq: int, d: int, offset=0):
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * (np.log(10000.0) / max(1, d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _block(self, key, stack: int, cross: bool):
        cfg = self.cfg
        d, dh = cfg.d_model, cfg.resolved_head_dim
        hq, hkv = cfg.num_heads * dh, cfg.num_kv_heads * dh
        ks = jax.random.split(key, 12)
        p = {
            "ln1_w": jnp.ones((stack, d)), "ln1_b": jnp.zeros((stack, d)),
            "wq": dense_init(ks[0], (stack, d, hq), in_axis=1),
            "wk": dense_init(ks[1], (stack, d, hkv), in_axis=1),
            "wv": dense_init(ks[2], (stack, d, hkv), in_axis=1),
            "wo": dense_init(ks[3], (stack, hq, d), in_axis=1),
            "ln2_w": jnp.ones((stack, d)), "ln2_b": jnp.zeros((stack, d)),
            "w1": dense_init(ks[4], (stack, d, cfg.d_ff), in_axis=1),
            "w2": dense_init(ks[5], (stack, cfg.d_ff, d), in_axis=1),
        }
        if cross:
            p.update({
                "lnx_w": jnp.ones((stack, d)), "lnx_b": jnp.zeros((stack, d)),
                "xq": dense_init(ks[6], (stack, d, hq), in_axis=1),
                "xk": dense_init(ks[7], (stack, d, hkv), in_axis=1),
                "xv": dense_init(ks[8], (stack, d, hkv), in_axis=1),
                "xo": dense_init(ks[9], (stack, hq, d), in_axis=1),
            })
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        return {
            "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
            "enc_blocks": self._block(ks[1], cfg.encoder_layers, cross=False),
            "enc_ln_w": jnp.ones((cfg.d_model,)),
            "enc_ln_b": jnp.zeros((cfg.d_model,)),
            "dec_blocks": self._block(ks[2], cfg.num_layers, cross=True),
            "dec_ln_w": jnp.ones((cfg.d_model,)),
            "dec_ln_b": jnp.zeros((cfg.d_model,)),
        }

    def param_axes(self) -> Params:
        def blk(cross):
            p = {"ln1_w": ("layers", "embed"), "ln1_b": ("layers", "embed"),
                 "wq": ("layers", "embed", "heads"),
                 "wk": ("layers", "embed", "kv_heads"),
                 "wv": ("layers", "embed", "kv_heads"),
                 "wo": ("layers", "heads", "embed"),
                 "ln2_w": ("layers", "embed"), "ln2_b": ("layers", "embed"),
                 "w1": ("layers", "embed", "mlp"),
                 "w2": ("layers", "mlp", "embed")}
            if cross:
                p.update({"lnx_w": ("layers", "embed"),
                          "lnx_b": ("layers", "embed"),
                          "xq": ("layers", "embed", "heads"),
                          "xk": ("layers", "embed", "kv_heads"),
                          "xv": ("layers", "embed", "kv_heads"),
                          "xo": ("layers", "heads", "embed")})
            return p
        return {
            "embed": ("vocab", "embed"),
            "enc_blocks": blk(False),
            "enc_ln_w": ("embed",), "enc_ln_b": ("embed",),
            "dec_blocks": blk(True),
            "dec_ln_w": ("embed",), "dec_ln_b": ("embed",),
        }

    # ---------------------------------------------------------------- blocks
    def _self_attn(self, lp, x, causal, positions=None):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, _ = x.shape
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"])
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
        q = constrain(q.reshape(b, s, cfg.num_heads, dh),
                      ("batch", None, "heads", None))
        k = constrain(k.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        v = constrain(v.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        g = cfg.num_heads // cfg.num_kv_heads
        kr, vr = k, v
        if g > 1:
            kr = constrain(jnp.repeat(k, g, axis=2),
                           ("batch", None, "heads", None))
            vr = constrain(jnp.repeat(v, g, axis=2),
                           ("batch", None, "heads", None))
        attn = flash_attention(q, kr, vr, cfg.num_heads, causal=causal,
                               block_q=cfg.attention_block_q,
                               block_kv=cfg.attention_block_kv)
        attn = attn.reshape(b, s, cfg.num_heads * dh)
        return x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"].astype(h.dtype)), \
            (k, v)

    def _cross_attn(self, lp, x, enc_k, enc_v):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, _ = x.shape
        h = layer_norm(x, lp["lnx_w"], lp["lnx_b"])
        q = jnp.einsum("bsd,dh->bsh", h, lp["xq"].astype(h.dtype))
        q = constrain(q.reshape(b, s, cfg.num_heads, dh),
                      ("batch", None, "heads", None))
        g = cfg.num_heads // cfg.num_kv_heads
        ek, ev = enc_k, enc_v
        if g > 1:
            ek = jnp.repeat(enc_k, g, axis=2)
            ev = jnp.repeat(enc_v, g, axis=2)
        attn = flash_attention(q, ek, ev, cfg.num_heads, causal=False,
                               block_q=cfg.attention_block_q,
                               block_kv=cfg.attention_block_kv)
        attn = attn.reshape(b, s, cfg.num_heads * dh)
        return x + jnp.einsum("bsh,hd->bsd", attn, lp["xo"].astype(h.dtype))

    def _mlp(self, lp, x):
        h = layer_norm(x, lp["ln2_w"], lp["ln2_b"])
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["w1"].astype(h.dtype)))
        return x + jnp.einsum("bsf,fd->bsd", h, lp["w2"].astype(h.dtype))

    def encode(self, params: Params, frames):
        """frames [B, T_enc, d] (stub frontend output)."""
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16) + \
            sinusoid_positions(frames.shape[1], cfg.d_model).astype(jnp.bfloat16)

        def body(x, lp):
            x, _ = self._self_attn(lp, x, causal=False)
            x = self._mlp(lp, x)
            return x, None
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return layer_norm(x, params["enc_ln_w"], params["enc_ln_b"])

    def _cross_kv(self, params: Params, enc_out):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, t, _ = enc_out.shape

        def body(_, lp):
            k = jnp.einsum("btd,dh->bth", enc_out, lp["xk"].astype(enc_out.dtype))
            v = jnp.einsum("btd,dh->bth", enc_out, lp["xv"].astype(enc_out.dtype))
            return None, (k.reshape(b, t, cfg.num_kv_heads, dh),
                          v.reshape(b, t, cfg.num_kv_heads, dh))
        _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
        return ks, vs

    def forward(self, params: Params, batch):
        """batch: {'frames': [B,T,d], 'tokens': [B,S]} -> (logits, aux)."""
        cfg = self.cfg
        frames, tokens = batch["frames"], batch["tokens"]
        enc = self.encode(params, frames)
        xk, xv = self._cross_kv(params, enc)
        b, s = tokens.shape
        x = embed_lookup(params["embed"], tokens) + \
            sinusoid_positions(s, cfg.d_model).astype(jnp.bfloat16)

        def body(x, xs):
            lp, ek, ev = xs
            x, _ = self._self_attn(lp, x, causal=True)
            x = self._cross_attn(lp, x, ek, ev)
            x = self._mlp(lp, x)
            return x, None

        fn = body
        if cfg.remat == "layer":
            fn = jax.checkpoint(body,
                                policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(fn, x, (params["dec_blocks"], xk, xv))
        x = layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
        return logits, jnp.zeros((), jnp.float32)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        l = cfg.num_layers
        return {
            "k": jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, dh), jnp.bfloat16),
            "v": jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, dh), jnp.bfloat16),
            "xk": jnp.zeros((l, batch, cfg.encoder_seq, cfg.num_kv_heads, dh),
                            jnp.bfloat16),
            "xv": jnp.zeros((l, batch, cfg.encoder_seq, cfg.num_kv_heads, dh),
                            jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        c = (None, "batch", "cache_seq", "kv_heads", None)
        # cross-KV is tiny (encoder_seq x kv x dh) and its 1500-frame axis
        # does not divide the mesh: keep it batch-sharded only
        x = (None, "batch", "enc_seq", "kv_heads", None)
        return {"k": c, "v": c, "xk": x, "xv": x, "length": ()}

    def prefill(self, params: Params, batch, max_seq: int):
        """Encode frames, precompute cross-KV, run decoder prompt."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        frames, tokens = batch["frames"], batch["tokens"]
        enc = self.encode(params, frames)
        xk, xv = self._cross_kv(params, enc)
        b, s = tokens.shape
        x = embed_lookup(params["embed"], tokens) + \
            sinusoid_positions(s, cfg.d_model).astype(jnp.bfloat16)

        def body(x, xs):
            lp, ek, ev = xs
            x, (k, v) = self._self_attn(lp, x, causal=True)
            x = self._cross_attn(lp, x, ek, ev)
            x = self._mlp(lp, x)
            kc = jnp.zeros((b, max_seq, cfg.num_kv_heads, dh), jnp.bfloat16)
            vc = jnp.zeros_like(kc)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(jnp.bfloat16), 0, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(jnp.bfloat16), 0, 1)
            return x, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(body, x, (params["dec_blocks"], xk, xv))
        x = layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
        logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(x.dtype))
        cache = {"k": kcs, "v": vcs,
                 "xk": xk.astype(jnp.bfloat16), "xv": xv.astype(jnp.bfloat16),
                 "length": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b = tokens.shape[0]
        pos = cache["length"]
        x = embed_lookup(params["embed"], tokens) + \
            sinusoid_positions(1, cfg.d_model, offset=pos).astype(jnp.bfloat16)

        def body(x, xs):
            lp, kc, vc, ek, ev = xs
            h = layer_norm(x, lp["ln1_w"], lp["ln1_b"])
            q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
            q = q.reshape(b, 1, cfg.num_heads, dh)
            k = k.reshape(b, 1, cfg.num_kv_heads, dh)
            v = v.reshape(b, 1, cfg.num_kv_heads, dh)
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(jnp.bfloat16), pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(jnp.bfloat16), pos, 1)
            attn = decode_attention(q, kc, vc, pos + 1, cfg.num_kv_heads)
            x = x + jnp.einsum("bsh,hd->bsd",
                               attn.reshape(b, 1, cfg.num_heads * dh),
                               lp["wo"].astype(h.dtype))
            # cross attention against precomputed encoder KV
            h = layer_norm(x, lp["lnx_w"], lp["lnx_b"])
            q = jnp.einsum("bsd,dh->bsh", h, lp["xq"].astype(h.dtype))
            q = q.reshape(b, 1, cfg.num_heads, dh)
            xattn = decode_attention(q, ek, ev, ek.shape[1], cfg.num_kv_heads)
            x = x + jnp.einsum("bsh,hd->bsd",
                               xattn.reshape(b, 1, cfg.num_heads * dh),
                               lp["xo"].astype(h.dtype))
            x = self._mlp(lp, x)
            return x, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
        logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(x.dtype))
        return logits, {"k": kcs, "v": vcs, "xk": cache["xk"],
                        "xv": cache["xv"], "length": pos + 1}
