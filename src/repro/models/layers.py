# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""Shared model layers: RMSNorm, RoPE, flash attention (pure-JAX online
softmax), GQA without KV materialization, SwiGLU FFN, dropless MoE with
sort-based dispatch, initializers.

Conventions:
* activations ``[B, S, d]``; attention heads ``[B, S, H, dh]``.
* params are plain dicts of jnp arrays; per-layer weights carry a leading
  ``L`` axis and are consumed by ``lax.scan`` (compile-time critical).
* compute dtype bf16, accumulation/loss fp32, params fp32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# activation-sharding context (set by the launcher; no-op in plain tests)
#
# Models are mesh-agnostic: they annotate activations with *logical* axes
# ("batch", "heads", "kv_heads", "expert", "cache_seq", ...); the launcher
# installs (mesh, rules) and `constrain` turns annotations into
# with_sharding_constraint.  This is what keeps GSPMD from inventing
# pathological reshard patterns around head reshapes (DESIGN.md §7).
# ---------------------------------------------------------------------------

_SHARDING_CTX: Optional[tuple] = None


def set_sharding_context(mesh, rules) -> None:
    global _SHARDING_CTX
    _SHARDING_CTX = (mesh, rules) if mesh is not None else None


def clear_sharding_context() -> None:
    set_sharding_context(None, None)


def constrain(x, axes: tuple):
    """Annotate activation x with logical axes; no-op without context."""
    if _SHARDING_CTX is None:
        return x
    mesh, rules = _SHARDING_CTX
    from jax.sharding import NamedSharding, PartitionSpec
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        parts.append(m)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*parts)))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std)


def embed_init(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.02


def embed_lookup(embed, tokens, dtype=jnp.bfloat16):
    """Embedding lookup that shards over a TP'd vocab axis.

    Under a sharding context the gather becomes a one-hot matmul (the
    MaxText trick): both forward and the backward *scatter-add* lower to
    dots partitioned over the vocab axis — a plain gather's backward
    otherwise materializes an unsharded f32 [V, d] grad buffer.
    """
    if _SHARDING_CTX is None:
        return embed[tokens].astype(dtype)
    v = embed.shape[0]
    one_hot = jax.nn.one_hot(tokens, v, dtype=dtype)
    return jnp.einsum("...v,vd->...d", one_hot, embed.astype(dtype))


# ---------------------------------------------------------------------------
# norms / positional
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight).astype(dt)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x [..., S, H, dh], positions [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq     # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]                           # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q [B,Sq,KV,G,dh] x k [B,Sk,KV,dh] -> [B,KV,G,Sq,Sk] (no KV repeat)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def naive_attention(q, k, v, num_kv_heads: int, *, causal: bool = True,
                    window: int = 0, q_offset=0):
    """Reference attention (tests + decode single-step)."""
    b, sq, h, dh = q.shape
    kv = num_kv_heads
    g = h // kv
    qg = q.reshape(b, sq, kv, g, dh)
    scores = _gqa_scores(qg, k) / np.sqrt(dh)                 # [B,KV,G,Sq,Sk]
    sk = k.shape[1]
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, dh)


def _visited_blocks(qi, bq, nq, nkv, bkv, sk, causal, window):
    """Static list of KV block indices block qi must visit."""
    lo = 0
    hi = nkv - 1
    if causal:
        hi = min(hi, ((qi + 1) * bq - 1) // bkv)
    if window:
        lo = max(lo, (qi * bq - window) // bkv)
    return list(range(lo, hi + 1))


def _block_mask(q_start, k_start, bq, bkv, sq, sk, causal, window):
    qpos = q_start + jnp.arange(bq)[:, None]
    kpos = k_start + jnp.arange(bkv)[None, :]
    mask = (kpos < sk) & (qpos < sq)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


def _flash_fwd(q, k, v, num_kv_heads, causal, window, block_q, block_kv):
    """Returns out [b,sq,h,dh] and lse [b,kv,g,sq] (for the custom VJP)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = num_kv_heads
    g = h // kv
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    nq = (sq + bq - 1) // bq
    nkv = (sk + bkv - 1) // bkv
    scale = 1.0 / np.sqrt(dh)
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nkv * bkv - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nkv * bkv - sk), (0, 0), (0, 0)))
    outs, lses = [], []
    for qi in range(nq):                       # static unroll over Q blocks
        q_blk = qp[:, qi * bq:(qi + 1) * bq].reshape(b, bq, kv, g, dh)
        acc = jnp.zeros((b, kv, g, bq, dh), jnp.float32)
        m_run = jnp.full((b, kv, g, bq), NEG_INF, jnp.float32)
        l_run = jnp.zeros((b, kv, g, bq), jnp.float32)
        for kj in _visited_blocks(qi, bq, nq, nkv, bkv, sk, causal, window):
            k_blk = kp[:, kj * bkv:(kj + 1) * bkv]
            v_blk = vp[:, kj * bkv:(kj + 1) * bkv]
            s = _gqa_scores(q_blk, k_blk) * scale      # [b,kv,g,bq,bkv] f32
            mask = _block_mask(qi * bq, kj * bkv, bq, bkv, sq, sk,
                               causal, window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            m_run = m_new
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        lse = jnp.where(l_run > 0, m_run + jnp.log(jnp.maximum(l_run, 1e-30)),
                        0.0)
        outs.append(jnp.moveaxis(out, 3, 1).reshape(b, bq, h, dh))
        lses.append(lse)
    out = jnp.concatenate(outs, axis=1)[:, :sq].astype(q.dtype)
    lse = jnp.concatenate(lses, axis=3)                # [b,kv,g,nq*bq]
    return out, lse


def _flash_bwd(q, k, v, out, lse, dout, num_kv_heads, causal, window,
               block_q, block_kv):
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = num_kv_heads
    g = h // kv
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    nq = (sq + bq - 1) // bq
    nkv = (sk + bkv - 1) // bkv
    scale = 1.0 / np.sqrt(dh)
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nkv * bkv - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nkv * bkv - sk), (0, 0), (0, 0)))
    dop = jnp.pad(dout, ((0, 0), (0, nq * bq - sq), (0, 0), (0, 0)))
    op = jnp.pad(out, ((0, 0), (0, nq * bq - sq), (0, 0), (0, 0)))
    dq = jnp.zeros_like(qp, dtype=jnp.float32)
    dk = jnp.zeros_like(kp, dtype=jnp.float32)
    dv = jnp.zeros_like(vp, dtype=jnp.float32)
    # D_i = rowsum(dO * O) per head
    d_all = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32), axis=-1)
    d_all = jnp.moveaxis(d_all.reshape(b, nq * bq, kv, g), 1, 3)  # [b,kv,g,S]
    for qi in range(nq):
        q_blk = qp[:, qi * bq:(qi + 1) * bq].reshape(b, bq, kv, g, dh)
        do_blk = dop[:, qi * bq:(qi + 1) * bq].reshape(b, bq, kv, g, dh)
        do_blk = jnp.moveaxis(do_blk, 1, 3)            # [b,kv,g,bq,dh]
        lse_blk = lse[:, :, :, qi * bq:(qi + 1) * bq]
        d_blk = d_all[:, :, :, qi * bq:(qi + 1) * bq]
        dq_acc = jnp.zeros((b, kv, g, bq, dh), jnp.float32)
        for kj in _visited_blocks(qi, bq, nq, nkv, bkv, sk, causal, window):
            k_blk = kp[:, kj * bkv:(kj + 1) * bkv]
            v_blk = vp[:, kj * bkv:(kj + 1) * bkv]
            s = _gqa_scores(q_blk, k_blk) * scale
            mask = _block_mask(qi * bq, kj * bkv, bq, bkv, sq, sk,
                               causal, window)
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])        # [b,kv,g,bq,bkv]
            dv_b = jnp.einsum("bkgqs,bkgqd->bskd", p,
                              do_blk.astype(jnp.float32))
            dp = jnp.einsum("bkgqd,bskd->bkgqs",
                            do_blk.astype(jnp.float32),
                            v_blk.astype(jnp.float32))
            ds = p * (dp - d_blk[..., None])
            dq_acc = dq_acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", ds, k_blk.astype(jnp.float32)) * scale
            dk_b = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                              jnp.moveaxis(q_blk, (1, 2, 3), (1, 2, 3))
                              .astype(jnp.float32)) * scale
            dk = dk.at[:, kj * bkv:(kj + 1) * bkv].add(dk_b)
            dv = dv.at[:, kj * bkv:(kj + 1) * bkv].add(dv_b)
        dq_blk = jnp.moveaxis(dq_acc, 3, 1).reshape(b, bq, h, dh)
        dq = dq.at[:, qi * bq:(qi + 1) * bq].set(dq_blk)
    return (dq[:, :sq].astype(q.dtype), dk[:, :sk].astype(k.dtype),
            dv[:, :sk].astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, num_kv_heads: int, causal: bool = True,
                    window: int = 0, block_q: int = 512,
                    block_kv: int = 1024):
    """IO-aware blocked attention with an explicit (flash) VJP.

    Forward streams KV blocks with online softmax (O(bq*bkv) live memory);
    backward recomputes per-block probabilities from the saved logsumexp —
    the FlashAttention recipe, in pure JAX.  Q-block loop is a *static*
    unroll so causal/windowed block skipping costs nothing at trace time
    and the HLO contains only the visited lower-triangle blocks (honest
    cost_analysis, no cond both-branch inflation).
    """
    out, _ = _flash_fwd(q, k, v, num_kv_heads, causal, window,
                        block_q, block_kv)
    return out


def _fa_fwd(q, k, v, num_kv_heads, causal, window, block_q, block_kv):
    out, lse = _flash_fwd(q, k, v, num_kv_heads, causal, window,
                          block_q, block_kv)
    return out, (q, k, v, out, lse)


def _fa_bwd(num_kv_heads, causal, window, block_q, block_kv, res, dout):
    q, k, v, out, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, out, lse, dout, num_kv_heads, causal,
                            window, block_q, block_kv)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# keep the old name importable for tests that compare against the reference
flash_attention_reference_path = naive_attention


def decode_attention(q, k_cache, v_cache, cache_len, num_kv_heads: int):
    """Single-position attention against a cache (q [B,1,H,dh])."""
    b, _, h, dh = q.shape
    kv = num_kv_heads
    g = h // kv
    qg = q.reshape(b, 1, kv, g, dh)
    s = _gqa_scores(qg, k_cache) / np.sqrt(dh)        # [B,KV,G,1,S]
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where(pos[None, None, None, None, :] < cache_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(gate(x)) * up(x) )."""
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE: dropless-ish sort-based dispatch (capacity-bounded, deterministic)
# ---------------------------------------------------------------------------

class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray      # load-balance loss (Switch-style)
    dropped_frac: jnp.ndarray


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, norm_topk: bool = True):
    """Token-choice top-k MoE with GROUPED sort-based dispatch.

    x [B,S,d]; router_w [d,E]; expert weights [E,d,f]/[E,f,d].

    Dispatch (sort / cumsum / scatter) runs independently per batch row
    (= per DP shard), so under pjit every dispatch op is device-local and
    the only cross-device movement is the expert all-to-all on the
    ("batch", "expert") constrained buffers.  A global-token dispatch
    formulation replicates the E*C buffer on every device — measured 11 TB
    of per-step all-reduce on granite-moe before this change (EXPERIMENTS.md
    §Perf iteration moe-1).  FLOPs scale with *active* experts.
    """
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                    # [B,S,k]
    if norm_topk:
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    capacity = int(np.ceil(s * top_k / num_experts * capacity_factor))

    def dispatch_row(xt, ti, tv):
        """xt [S,d]; ti/tv [S,k] -> buf [E*C,d], (dest, src_token, w, keep)."""
        flat_e = ti.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(s), top_k)
        flat_w = tv.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(flat_e, length=num_experts)
        seg_off = jnp.cumsum(counts) - counts
        pos = jnp.arange(s * top_k) - seg_off[se]
        keep = pos < capacity
        dest = jnp.where(keep, se * capacity + pos, num_experts * capacity)
        buf = jnp.zeros((num_experts * capacity, d), xt.dtype)
        buf = buf.at[dest].set(xt[st], mode="drop")
        return buf, dest, st, sw, keep

    bufs, dest, st, sw, keep = jax.vmap(dispatch_row)(x, topi, topv)
    ein = constrain(bufs.reshape(b, num_experts, capacity, d),
                    ("batch", "expert", None, None))
    g = jnp.einsum("becd,edf->becf", ein, w_gate.astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", ein, w_up.astype(x.dtype))
    eout = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))
    eout = constrain(eout, ("batch", "expert", None, None))
    eflat = eout.reshape(b, num_experts * capacity, d)

    def combine_row(erow, dest_r, st_r, sw_r, keep_r):
        contrib = erow[jnp.clip(dest_r, 0, num_experts * capacity - 1)]
        contrib = contrib * (sw_r * keep_r)[:, None].astype(erow.dtype)
        return jnp.zeros((s, d), erow.dtype).at[st_r].add(contrib)

    y = jax.vmap(combine_row)(eflat, dest, st, sw, keep)

    # Switch-style load balance loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi[..., 0], num_experts), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = num_experts * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - jnp.sum(keep) / (b * s * top_k)
    return y, MoEMetrics(aux, dropped)
