# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""Mamba-2 (SSD — state-space duality) language model [arXiv:2405.21060].

Chunked SSD forward: within a chunk the recurrence is computed in its dual
quadratic-attention form (L x L decay-masked scores), across chunks a linear
recurrence carries the [heads, headdim, state] SSM state — the standard
work-optimal formulation.  Decode is the O(1)-per-token recurrence, which is
why this arch (and Griffin) carry the ``long_500k`` cell the full-attention
archs must skip.

Layer: in_proj -> (z gate | xBC | dt), causal conv1d(width 4) on xBC, SSD,
gated RMSNorm, out_proj.  Scanned over layers like the transformer.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import constrain, dense_init, embed_init, embed_lookup, rms_norm

Params = Dict[str, Any]


def _ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int, h_init=None):
    """Chunked SSD.

    x [b,s,h,p]; dt [b,s,h] (>0); a_log [h] (A = -exp(a_log));
    bmat/cmat [b,s,g,n]; returns y [b,s,h,p], h_final [b,h,p,n]
    (h_init likewise; zero if None).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // l

    a = -jnp.exp(a_log.astype(jnp.float32))                     # [h] < 0
    xg = x.reshape(b, nc, l, g, hpg, p)
    dtg = dt.reshape(b, nc, l, g, hpg).astype(jnp.float32)
    bg = bmat.reshape(b, nc, l, g, n)
    cg = cmat.reshape(b, nc, l, g, n)
    la = dtg * a.reshape(g, hpg)                                # log a_t
    lc = jnp.cumsum(la, axis=2)                                 # inclusive

    # intra-chunk (dual quadratic form)
    scores = jnp.einsum("bctgn,bcsgn->bcgts", cg, bg,
                        preferred_element_type=jnp.float32)     # [b,nc,g,L,L]
    decay = lc[:, :, :, None, :, :] - lc[:, :, None, :, :, :]   # [b,nc,t,s,g,hpg]
    tril = (jnp.arange(l)[:, None] >= jnp.arange(l)[None, :])
    w = jnp.where(tril[None, None, :, :, None, None],
                  jnp.exp(decay), 0.0)
    w = w * dtg[:, :, None, :, :, :]                            # dt_s factor
    w = w * jnp.transpose(scores, (0, 1, 3, 4, 2))[..., None]   # bcgts->bcts g, bcast hpg
    y_intra = jnp.einsum("btsgh,bsghp->btghp",
                         w.reshape(b * nc, l, l, g, hpg),
                         xg.reshape(b * nc, l, g, hpg, p))
    y_intra = y_intra.reshape(b, nc, l, g, hpg, p)

    # chunk-final states
    sdecay = jnp.exp(lc[:, :, -1:, :, :] - lc) * dtg            # [b,nc,L,g,hpg]
    s_chunk = jnp.einsum("bclgn,bclgh,bclghp->bcghpn",
                         bg, sdecay, xg.astype(jnp.float32))

    # inter-chunk recurrence
    cdecay = jnp.exp(lc[:, :, -1, :, :])                        # [b,nc,g,hpg]
    h0 = jnp.zeros((b, g, hpg, p, n), jnp.float32) if h_init is None \
        else h_init.reshape(b, g, hpg, p, n).astype(jnp.float32)

    def step(hprev, inp):
        dcy, s_c = inp
        h_new = dcy[..., None, None] * hprev + s_c
        return h_new, hprev

    (h_fin, h_ins) = jax.lax.scan(
        step, h0, (jnp.moveaxis(cdecay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    h_in = jnp.moveaxis(h_ins, 0, 1)                            # state entering c

    y_inter = jnp.einsum("bclgn,bclgh,bcghpn->bclghp",
                         cg, jnp.exp(lc), h_in)
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), h_fin.reshape(b, h, p, n)


def _ssd_step(xt, dtt, a_log, bt, ct, h):
    """Single-token recurrence. xt [b,h,p], dtt [b,h], bt/ct [b,g,n],
    h [b,h,p,n]."""
    b, hh, p = xt.shape
    g = bt.shape[1]
    hpg = hh // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtt = dtt.astype(jnp.float32)
    decay = jnp.exp(dtt * a)                                   # [b,h]
    bx = jnp.einsum("bghp,bgn,bgh->bghpn",
                    xt.astype(jnp.float32).reshape(b, g, hpg, p),
                    bt.astype(jnp.float32),
                    dtt.reshape(b, g, hpg)).reshape(b, hh, p, -1)
    h_new = decay[..., None, None] * h + bx
    y = jnp.einsum("bghpn,bgn->bghp",
                   h_new.reshape(b, g, hpg, p, -1), ct).reshape(b, hh, p)
    return y.astype(xt.dtype), h_new


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d. x [b,s,c], w [k,c]; state [b,k-1,c] or None.
    Returns y [b,s,c], new state [b,k-1,c]."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    return y, xp[:, -(k - 1):] if k > 1 else state


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _dims(self):
        cfg = self.cfg
        di = cfg.ssm_d_inner
        nh = cfg.ssm_heads
        g, n = cfg.ssm_groups, cfg.ssm_state
        return di, nh, g, n

    def init(self, key) -> Params:
        """Projections are SEPARATE weights (wz/wx/wb/wc/wdt) rather than
        one fused in_proj: slicing a TP-sharded fused output at offsets
        that cross shard boundaries makes GSPMD reshard every layer —
        split projections shard cleanly (z/x on 'mlp'; the small B/C/dt
        heads replicated).  Depthwise conv splits the same way."""
        cfg = self.cfg
        l, d, vp = cfg.num_layers, cfg.d_model, cfg.padded_vocab
        di, nh, g, n = self._dims()
        k = cfg.ssm_conv_width
        keys = jax.random.split(key, 12)
        layers = {
            "norm": jnp.ones((l, d), jnp.float32),
            "wz": dense_init(keys[0], (l, d, di), in_axis=1),
            "wx": dense_init(keys[1], (l, d, di), in_axis=1),
            "wb": dense_init(keys[2], (l, d, g * n), in_axis=1),
            "wc": dense_init(keys[3], (l, d, g * n), in_axis=1),
            "wdt": dense_init(keys[4], (l, d, nh), in_axis=1),
            "conv_x": dense_init(keys[5], (l, k, di), in_axis=1) * 0.5,
            "conv_b": dense_init(keys[6], (l, k, g * n), in_axis=1) * 0.5,
            "conv_c": dense_init(keys[7], (l, k, g * n), in_axis=1) * 0.5,
            "dt_bias": jnp.zeros((l, nh), jnp.float32),
            "a_log": jnp.zeros((l, nh), jnp.float32),
            "d_skip": jnp.ones((l, nh), jnp.float32),
            "out_norm": jnp.ones((l, di), jnp.float32),
            "out_proj": dense_init(keys[8], (l, di, d), in_axis=1),
        }
        return {
            "embed": embed_init(keys[9], (vp, d)),
            "final_norm": jnp.ones((d,), jnp.float32),
            "lm_head": dense_init(keys[10], (d, vp)),
            "layers": layers,
        }

    def param_axes(self) -> Params:
        return {
            "embed": ("vocab", "embed"),
            "final_norm": ("embed",),
            "lm_head": ("embed", "vocab"),
            "layers": {
                "norm": ("layers", "embed"),
                "wz": ("layers", "embed", "mlp"),
                "wx": ("layers", "embed", "mlp"),
                "wb": ("layers", "embed", None),
                "wc": ("layers", "embed", None),
                "wdt": ("layers", "embed", None),
                "conv_x": ("layers", None, "mlp"),
                "conv_b": ("layers", None, None),
                "conv_c": ("layers", None, None),
                "dt_bias": ("layers", None),
                "a_log": ("layers", None),
                "d_skip": ("layers", None),
                "out_norm": ("layers", "mlp"),
                "out_proj": ("layers", "mlp", "embed"),
            },
        }

    def _layer_core(self, lp, x, conv_state=None, ssm_state=None,
                    single_step=False):
        cfg = self.cfg
        di, nh, g, n = self._dims()
        p = cfg.ssm_head_dim
        bsz = x.shape[0]
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        z = constrain(jnp.einsum("bsd,do->bso", h, lp["wz"].astype(h.dtype)),
                      ("batch", None, "mlp"))
        xs = constrain(jnp.einsum("bsd,do->bso", h, lp["wx"].astype(h.dtype)),
                       ("batch", None, "mlp"))
        braw = jnp.einsum("bsd,do->bso", h, lp["wb"].astype(h.dtype))
        craw = jnp.einsum("bsd,do->bso", h, lp["wc"].astype(h.dtype))
        dt = jnp.einsum("bsd,do->bso", h, lp["wdt"].astype(h.dtype))
        cs_x = conv_state[0] if conv_state is not None else None
        cs_b = conv_state[1] if conv_state is not None else None
        cs_c = conv_state[2] if conv_state is not None else None
        xs, nc_x = _causal_conv(xs, lp["conv_x"], cs_x)
        braw, nc_b = _causal_conv(braw, lp["conv_b"], cs_b)
        craw, nc_c = _causal_conv(craw, lp["conv_c"], cs_c)
        new_conv = (nc_x, nc_b, nc_c)
        xs = jax.nn.silu(xs)
        bmat = jax.nn.silu(braw).reshape(*braw.shape[:-1], g, n)
        cmat = jax.nn.silu(craw).reshape(*craw.shape[:-1], g, n)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
        if single_step:
            xt = xs[:, 0].reshape(bsz, nh, p)
            y, new_ssm = _ssd_step(xt, dt[:, 0], lp["a_log"],
                                   bmat[:, 0], cmat[:, 0], ssm_state)
            y = y + lp["d_skip"].astype(jnp.float32)[None, :, None] * \
                xt.astype(jnp.float32)
            y = y.reshape(bsz, 1, di).astype(x.dtype)
        else:
            s = xs.shape[1]
            xh = xs.reshape(bsz, s, nh, p)
            y, new_ssm = _ssd_chunked(xh, dt, lp["a_log"], bmat, cmat,
                                      cfg.ssm_chunk, ssm_state)
            y = y + (lp["d_skip"][None, None, :, None] *
                     xh.astype(jnp.float32)).astype(y.dtype)
            y = y.reshape(bsz, s, di)
        y = y * jax.nn.silu(z)
        y = rms_norm(y, lp["out_norm"], cfg.norm_eps)
        out = jnp.einsum("bso,od->bsd", y, lp["out_proj"].astype(y.dtype))
        return x + out, new_conv, new_ssm

    def forward(self, params: Params, tokens):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)
        layer = self._layer_core
        if cfg.remat == "layer":
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable)

        def body(carry, lp):
            y, _, _ = layer(lp, carry)
            return y, None

        x, _ = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
        return logits, jnp.zeros((), jnp.float32)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        di, nh, g, n = self._dims()
        p = cfg.ssm_head_dim
        l = cfg.num_layers
        k = cfg.ssm_conv_width
        return {
            "conv_x": jnp.zeros((l, batch, k - 1, di), jnp.bfloat16),
            "conv_b": jnp.zeros((l, batch, k - 1, g * n), jnp.bfloat16),
            "conv_c": jnp.zeros((l, batch, k - 1, g * n), jnp.bfloat16),
            "ssm": jnp.zeros((l, batch, nh, p, n), jnp.float32),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        return {"conv_x": (None, "batch", None, "mlp"),
                "conv_b": (None, "batch", None, None),
                "conv_c": (None, "batch", None, None),
                "ssm": (None, "batch", "mlp_heads", None, None),
                "length": ()}

    def prefill(self, params: Params, tokens, max_seq: int):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)

        def body(carry, lp):
            y, conv, ssm = self._layer_core(lp, carry)
            return y, (conv[0].astype(jnp.bfloat16),
                       conv[1].astype(jnp.bfloat16),
                       conv[2].astype(jnp.bfloat16), ssm)

        x, (cx, cb, cc, ssms) = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            params["lm_head"].astype(x.dtype))
        cache = {"conv_x": cx, "conv_b": cb, "conv_c": cc, "ssm": ssms,
                 "length": jnp.asarray(tokens.shape[1], jnp.int32)}
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)      # [B,1,d]

        def body(carry, xs):
            lp, cx, cb, cc, ssm = xs
            y, new_conv, new_ssm = self._layer_core(
                lp, carry,
                (cx.astype(carry.dtype), cb.astype(carry.dtype),
                 cc.astype(carry.dtype)), ssm, single_step=True)
            return y, (new_conv[0].astype(jnp.bfloat16),
                       new_conv[1].astype(jnp.bfloat16),
                       new_conv[2].astype(jnp.bfloat16), new_ssm)

        x, (cx, cb, cc, ssms) = jax.lax.scan(
            body, x, (params["layers"], cache["conv_x"], cache["conv_b"],
                      cache["conv_c"], cache["ssm"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            params["lm_head"].astype(x.dtype))
        return logits, {"conv_x": cx, "conv_b": cb, "conv_c": cc,
                        "ssm": ssms, "length": cache["length"] + 1}
