# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""Decoder-only transformer LM covering the dense, MoE and VLM-token
architectures (smollm x2, llama3.2-3b, granite-8b, chameleon-34b,
granite-moe, qwen2-moe).

* layers are stacked along a leading L axis and consumed by ``lax.scan``
  (one trace regardless of depth — compile-time critical for the 512-device
  dry-run on this 1-core host);
* attention = pure-JAX flash attention (layers.py), GQA without KV repeat;
* MoE FFN = sort-based capacity-bounded dispatch (active-FLOPs faithful);
* KV-cache prefill/decode paths for the serving shapes.

Param logical axes (for pjit sharding) come from ``param_axes()`` — a tree
congruent with ``init()``'s output.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    constrain,
    embed_lookup,
    decode_attention,
    dense_init,
    embed_init,
    flash_attention,
    moe_ffn,
    rms_norm,
    rope,
    swiglu,
)

Params = Dict[str, Any]


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        l = cfg.num_layers
        keys = jax.random.split(key, 16)
        d, f, vp = cfg.d_model, cfg.d_ff, cfg.padded_vocab

        def stack(k, shape):
            return dense_init(k, (l,) + shape, in_axis=1)

        layers = {
            "attn_norm": jnp.ones((l, d), jnp.float32),
            "wq": stack(keys[0], (d, cfg.num_heads * dh)),
            "wk": stack(keys[1], (d, cfg.num_kv_heads * dh)),
            "wv": stack(keys[2], (d, cfg.num_kv_heads * dh)),
            "wo": stack(keys[3], (cfg.num_heads * dh, d)),
            "ffn_norm": jnp.ones((l, d), jnp.float32),
        }
        if cfg.num_experts:
            e, fe = cfg.num_experts, cfg.moe_d_ff
            layers.update({
                "router": stack(keys[4], (d, e)),
                "e_gate": dense_init(keys[5], (l, e, d, fe), in_axis=2),
                "e_up": dense_init(keys[6], (l, e, d, fe), in_axis=2),
                "e_down": dense_init(keys[7], (l, e, fe, d), in_axis=2),
            })
            if cfg.num_shared_experts:
                fs = cfg.num_shared_experts * fe
                layers.update({
                    "s_gate": stack(keys[8], (d, fs)),
                    "s_up": stack(keys[9], (d, fs)),
                    "s_down": stack(keys[10], (fs, d)),
                })
        else:
            layers.update({
                "w_gate": stack(keys[4], (d, f)),
                "w_up": stack(keys[5], (d, f)),
                "w_down": stack(keys[6], (f, d)),
            })
        params = {
            "embed": embed_init(keys[11], (vp, d)),
            "final_norm": jnp.ones((d,), jnp.float32),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[12], (d, vp))
        return params

    def param_axes(self) -> Params:
        cfg = self.cfg
        layers = {
            "attn_norm": ("layers", "embed"),
            "wq": ("layers", "embed", "heads"),
            "wk": ("layers", "embed", "kv_heads"),
            "wv": ("layers", "embed", "kv_heads"),
            "wo": ("layers", "heads", "embed"),
            "ffn_norm": ("layers", "embed"),
        }
        if cfg.num_experts:
            layers.update({
                "router": ("layers", "embed", None),
                "e_gate": ("layers", "expert", "embed", "expert_mlp"),
                "e_up": ("layers", "expert", "embed", "expert_mlp"),
                "e_down": ("layers", "expert", "expert_mlp", "embed"),
            })
            if cfg.num_shared_experts:
                layers.update({
                    "s_gate": ("layers", "embed", "mlp"),
                    "s_up": ("layers", "embed", "mlp"),
                    "s_down": ("layers", "mlp", "embed"),
                })
        else:
            layers.update({
                "w_gate": ("layers", "embed", "mlp"),
                "w_up": ("layers", "embed", "mlp"),
                "w_down": ("layers", "mlp", "embed"),
            })
        axes = {
            "embed": ("vocab", "embed"),
            "final_norm": ("embed",),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            axes["lm_head"] = ("embed", "vocab")
        return axes

    # --------------------------------------------------------------- forward
    def _layer(self, x, lp, positions):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, d = x.shape
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(x.dtype))
        q = constrain(q.reshape(b, s, cfg.num_heads, dh),
                      ("batch", None, "heads", None))
        k = constrain(k.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        v = constrain(v.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # Megatron-style: repeat local KV to full heads so q/k/v share one
        # clean head sharding through the flash blocks (repeat is local)
        g = cfg.num_heads // cfg.num_kv_heads
        if g > 1:
            k = constrain(jnp.repeat(k, g, axis=2),
                          ("batch", None, "heads", None))
            v = constrain(jnp.repeat(v, g, axis=2),
                          ("batch", None, "heads", None))
        attn = flash_attention(q, k, v, cfg.num_heads, causal=True,
                               block_q=cfg.attention_block_q,
                               block_kv=cfg.attention_block_kv)
        attn = attn.reshape(b, s, cfg.num_heads * dh)
        x = x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"].astype(x.dtype))
        x = constrain(x, ("batch", "seq_sp", None))

        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.num_experts:
            y, metrics = moe_ffn(
                h, lp["router"], lp["e_gate"], lp["e_up"], lp["e_down"],
                num_experts=cfg.num_experts,
                top_k=cfg.num_experts_per_token,
                capacity_factor=cfg.moe_capacity_factor,
                norm_topk=cfg.norm_topk_prob)
            if cfg.num_shared_experts:
                y = y + swiglu(h, lp["s_gate"], lp["s_up"], lp["s_down"])
            aux = metrics.aux_loss
        else:
            y = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return constrain(x + y, ("batch", "seq_sp", None)), aux

    def forward(self, params: Params, tokens: jnp.ndarray):
        """tokens [B,S] -> (logits [B,S,Vp] in bf16, aux loss scalar)."""
        cfg = self.cfg
        b, s = tokens.shape
        x = constrain(embed_lookup(params["embed"], tokens),
                      ("batch", "seq_sp", None))
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        layer = self._layer
        if cfg.remat == "layer":
            layer = jax.checkpoint(layer,
                                   policy=jax.checkpoint_policies.nothing_saveable)
        elif cfg.remat == "dots":
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        def body(carry, lp):
            y, aux = layer(carry, lp, positions)
            return y, aux

        x, auxs = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return logits, jnp.mean(auxs)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, dh)
        return {
            "k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        return {"k": (None, "batch", "cache_seq", "kv_heads", None),
                "v": (None, "batch", "cache_seq", "kv_heads", None),
                "length": ()}

    def prefill(self, params: Params, tokens: jnp.ndarray, max_seq: int):
        """Full-sequence forward that also emits the KV cache."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s = tokens.shape
        x = constrain(embed_lookup(params["embed"], tokens),
                      ("batch", "seq_sp", None))
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def body(carry, lp):
            h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
            q = constrain(q.reshape(b, s, cfg.num_heads, dh),
                          ("batch", None, "heads", None))
            k = constrain(k.reshape(b, s, cfg.num_kv_heads, dh),
                          ("batch", None, "kv_heads", None))
            v = constrain(v.reshape(b, s, cfg.num_kv_heads, dh),
                          ("batch", None, "kv_heads", None))
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            g = cfg.num_heads // cfg.num_kv_heads
            kr, vr = k, v
            if g > 1:
                kr = constrain(jnp.repeat(k, g, axis=2),
                               ("batch", None, "heads", None))
                vr = constrain(jnp.repeat(v, g, axis=2),
                               ("batch", None, "heads", None))
            attn = flash_attention(q, kr, vr, cfg.num_heads, causal=True,
                                   block_q=cfg.attention_block_q,
                                   block_kv=cfg.attention_block_kv)
            attn = attn.reshape(b, s, cfg.num_heads * dh)
            x2 = carry + jnp.einsum("bsh,hd->bsd", attn, lp["wo"].astype(h.dtype))
            h2 = rms_norm(x2, lp["ffn_norm"], cfg.norm_eps)
            if cfg.num_experts:
                y, _ = moe_ffn(h2, lp["router"], lp["e_gate"], lp["e_up"],
                               lp["e_down"], num_experts=cfg.num_experts,
                               top_k=cfg.num_experts_per_token,
                               capacity_factor=cfg.moe_capacity_factor,
                               norm_topk=cfg.norm_topk_prob)
                if cfg.num_shared_experts:
                    y = y + swiglu(h2, lp["s_gate"], lp["s_up"], lp["s_down"])
            else:
                y = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
            kc = jnp.zeros((b, max_seq, cfg.num_kv_heads, dh), jnp.bfloat16)
            vc = jnp.zeros_like(kc)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(jnp.bfloat16), 0, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(jnp.bfloat16), 0, 1)
            return constrain(x2 + y, ("batch", "seq_sp", None)), (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(x.dtype))
        cache = {"k": kcs, "v": vcs, "length": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode_step(self, params: Params, cache, tokens: jnp.ndarray):
        """One decode step. tokens [B,1]; cache as from init_cache/prefill."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b = tokens.shape[0]
        pos = cache["length"]
        x = embed_lookup(params["embed"], tokens)               # [B,1,d]
        positions = jnp.full((b, 1), pos, jnp.int32)

        def body(carry, xs):
            lp, kc, vc = xs
            h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
            q = constrain(q.reshape(b, 1, cfg.num_heads, dh),
                          ("batch", None, None, None))
            k = constrain(k.reshape(b, 1, cfg.num_kv_heads, dh),
                          ("batch", None, "kv_heads", None))
            v = constrain(v.reshape(b, 1, cfg.num_kv_heads, dh),
                          ("batch", None, "kv_heads", None))
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(jnp.bfloat16), pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(jnp.bfloat16), pos, 1)
            attn = decode_attention(q, kc, vc, pos + 1, cfg.num_kv_heads)
            attn = attn.reshape(b, 1, cfg.num_heads * dh)
            x2 = carry + jnp.einsum("bsh,hd->bsd", attn, lp["wo"].astype(h.dtype))
            h2 = rms_norm(x2, lp["ffn_norm"], cfg.norm_eps)
            if cfg.num_experts:
                y, _ = moe_ffn(h2, lp["router"], lp["e_gate"], lp["e_up"],
                               lp["e_down"], num_experts=cfg.num_experts,
                               top_k=cfg.num_experts_per_token,
                               capacity_factor=max(2.0, cfg.moe_capacity_factor),
                               norm_topk=cfg.norm_topk_prob)
                if cfg.num_shared_experts:
                    y = y + swiglu(h2, lp["s_gate"], lp["s_up"], lp["s_down"])
            else:
                y = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
            return x2 + y, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                               cache["v"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(x.dtype))
        new_cache = {"k": kcs, "v": vcs, "length": pos + 1}
        return logits, new_cache
