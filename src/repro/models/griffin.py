# repro-lint: legacy seed-era LM model zoo, no graph-facade consumers
"""RecurrentGemma / Griffin hybrid [arXiv:2402.19427].

Block pattern (RG-LRU, RG-LRU, local attention) with an MLP after every
temporal block.  To keep ``lax.scan`` over depth with *static* heterogeneous
structure (no ``cond`` branches polluting HLO cost analysis), layers are
scanned in groups of three; ``num_layers % 3`` trailing recurrent layers are
a separately-scanned tail (26 = 8 groups + 2 tail for the assigned config).

RG-LRU: r_t = sigmoid(W_a x), i_t = sigmoid(W_x x),
        log a_t = -c * r_t * softplus(-Lambda)   (a = sigmoid(Lambda)^{c r})
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
computed with ``jax.lax.associative_scan`` (log-depth — this is what makes
the 512k-token cell trainable-shaped) and a 1-step recurrence for decode.
Local attention uses a *ring-buffer* KV cache of size ``local_window`` so the
long_500k decode cell carries O(window) state, not O(S).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    constrain,
    embed_lookup,
    decode_attention,
    dense_init,
    embed_init,
    flash_attention,
    rms_norm,
    rope,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rglru_scan(x, r, i, lam, c: float, h0=None):
    """x,r,i [b,s,w]; lam [w]; returns y [b,s,w], h_final [b,w]."""
    log_a = (-c) * r.astype(jnp.float32) * jax.nn.softplus(-lam)
    a = jnp.exp(log_a)
    gated = (i.astype(jnp.float32) * x.astype(jnp.float32)) * \
        jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    if h0 is not None:
        # fold the incoming state into the first element
        first = a[:, 0] * h0.astype(jnp.float32) + gated[:, 0]
        gated = gated.at[:, 0].set(first)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _rglru_step(x, r, i, lam, c: float, h):
    log_a = (-c) * r.astype(jnp.float32) * jax.nn.softplus(-lam)
    a = jnp.exp(log_a)
    h_new = a * h.astype(jnp.float32) + \
        jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i.astype(jnp.float32) * x.astype(jnp.float32))
    return h_new.astype(x.dtype), h_new


def _causal_conv(x, w, state=None):
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, j:j + x.shape[1]] * w[j].astype(x.dtype) for j in range(k))
    return y, xp[:, -(k - 1):]


class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = cfg.num_layers // 3
        self.tail = cfg.num_layers % 3          # trailing recurrent layers

    # ------------------------------------------------------------------ init
    def _rec_params(self, key, stack: int):
        cfg = self.cfg
        d, w = cfg.d_model, cfg.rnn_width
        ks = jax.random.split(key, 6)
        return {
            "norm": jnp.ones((stack, d), jnp.float32),
            "proj_x": dense_init(ks[0], (stack, d, w), in_axis=1),
            "proj_gate": dense_init(ks[1], (stack, d, w), in_axis=1),
            "conv_w": dense_init(ks[2], (stack, 4, w), in_axis=1) * 0.5,
            "wa": dense_init(ks[3], (stack, w, w), in_axis=1),
            "wx": dense_init(ks[4], (stack, w, w), in_axis=1),
            "lam": jnp.full((stack, w), 2.0, jnp.float32),
            "proj_out": dense_init(ks[5], (stack, w, d), in_axis=1),
        }

    def _attn_params(self, key, stack: int):
        cfg = self.cfg
        d, dh = cfg.d_model, cfg.resolved_head_dim
        ks = jax.random.split(key, 4)
        return {
            "norm": jnp.ones((stack, d), jnp.float32),
            "wq": dense_init(ks[0], (stack, d, cfg.num_heads * dh), in_axis=1),
            "wk": dense_init(ks[1], (stack, d, cfg.num_kv_heads * dh), in_axis=1),
            "wv": dense_init(ks[2], (stack, d, cfg.num_kv_heads * dh), in_axis=1),
            "wo": dense_init(ks[3], (stack, cfg.num_heads * dh, d), in_axis=1),
        }

    def _mlp_params(self, key, stack: int):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 3)
        return {
            "norm": jnp.ones((stack, d), jnp.float32),
            "w_gate": dense_init(ks[0], (stack, d, f), in_axis=1),
            "w_up": dense_init(ks[1], (stack, d, f), in_axis=1),
            "w_down": dense_init(ks[2], (stack, f, d), in_axis=1),
        }

    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 12)
        g = self.groups
        params = {
            "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model)),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": dense_init(keys[1], (cfg.d_model, cfg.padded_vocab)),
            "groups": {
                "rec1": self._rec_params(keys[2], g),
                "mlp1": self._mlp_params(keys[3], g),
                "rec2": self._rec_params(keys[4], g),
                "mlp2": self._mlp_params(keys[5], g),
                "attn": self._attn_params(keys[6], g),
                "mlp3": self._mlp_params(keys[7], g),
            },
        }
        if self.tail:
            params["tail"] = {
                "rec": self._rec_params(keys[8], self.tail),
                "mlp": self._mlp_params(keys[9], self.tail),
            }
        return params

    def param_axes(self) -> Params:
        rec = {"norm": ("layers", "embed"),
               "proj_x": ("layers", "embed", "mlp"),
               "proj_gate": ("layers", "embed", "mlp"),
               "conv_w": ("layers", None, "mlp"),
               "wa": ("layers", "mlp", "mlp2"),
               "wx": ("layers", "mlp", "mlp2"),
               "lam": ("layers", "mlp"),
               "proj_out": ("layers", "mlp", "embed")}
        attn = {"norm": ("layers", "embed"),
                "wq": ("layers", "embed", "heads"),
                "wk": ("layers", "embed", "kv_heads"),
                "wv": ("layers", "embed", "kv_heads"),
                "wo": ("layers", "heads", "embed")}
        mlp = {"norm": ("layers", "embed"),
               "w_gate": ("layers", "embed", "mlp"),
               "w_up": ("layers", "embed", "mlp"),
               "w_down": ("layers", "mlp", "embed")}
        axes = {
            "embed": ("vocab", "embed"),
            "final_norm": ("embed",),
            "lm_head": ("embed", "vocab"),
            "groups": {"rec1": rec, "mlp1": mlp, "rec2": dict(rec),
                       "mlp2": dict(mlp), "attn": attn, "mlp3": dict(mlp)},
        }
        if self.tail:
            axes["tail"] = {"rec": dict(rec), "mlp": dict(mlp)}
        return axes

    # ---------------------------------------------------------------- blocks
    def _rec_block(self, lp, x, conv_state=None, h_state=None,
                   single_step=False):
        cfg = self.cfg
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        main = constrain(
            jnp.einsum("bsd,dw->bsw", h, lp["proj_x"].astype(h.dtype)),
            ("batch", None, "mlp"))
        gate = jax.nn.gelu(constrain(
            jnp.einsum("bsd,dw->bsw", h, lp["proj_gate"].astype(h.dtype)),
            ("batch", None, "mlp")))
        main, new_conv = _causal_conv(main, lp["conv_w"], conv_state)
        r = jax.nn.sigmoid(
            jnp.einsum("bsw,wu->bsu", main, lp["wa"].astype(main.dtype)))
        i = jax.nn.sigmoid(
            jnp.einsum("bsw,wu->bsu", main, lp["wx"].astype(main.dtype)))
        if single_step:
            y1, new_h = _rglru_step(main[:, 0], r[:, 0], i[:, 0], lp["lam"],
                                    cfg.rglru_c, h_state)
            y = y1[:, None]
        else:
            y, new_h = _rglru_scan(main, r, i, lp["lam"], cfg.rglru_c, h_state)
        y = y * gate
        out = jnp.einsum("bsw,wd->bsd", y, lp["proj_out"].astype(y.dtype))
        return x + out, new_conv, new_h

    def _mlp_block(self, lp, x):
        cfg = self.cfg
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        g = jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", h, lp["w_gate"].astype(h.dtype)))
        u = jnp.einsum("bsd,df->bsf", h, lp["w_up"].astype(h.dtype))
        return x + jnp.einsum("bsf,fd->bsd", g * u,
                              lp["w_down"].astype(h.dtype))

    def _attn_block(self, lp, x, positions):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, _ = x.shape
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
        q = constrain(q.reshape(b, s, cfg.num_heads, dh),
                      ("batch", None, "heads", None))
        k = constrain(k.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        v = constrain(v.reshape(b, s, cfg.num_kv_heads, dh),
                      ("batch", None, "kv_heads", None))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        g = cfg.num_heads // cfg.num_kv_heads
        kr, vr = k, v
        if g > 1:
            kr = constrain(jnp.repeat(k, g, axis=2),
                           ("batch", None, "heads", None))
            vr = constrain(jnp.repeat(v, g, axis=2),
                           ("batch", None, "heads", None))
        attn = flash_attention(q, kr, vr, cfg.num_heads, causal=True,
                               window=cfg.local_window,
                               block_q=cfg.attention_block_q,
                               block_kv=cfg.attention_block_kv)
        attn = attn.reshape(b, s, cfg.num_heads * dh)
        return x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"].astype(h.dtype)), \
            (k, v)

    # --------------------------------------------------------------- forward
    def forward(self, params: Params, tokens):
        cfg = self.cfg
        b, s = tokens.shape
        x = embed_lookup(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def group_fn(x, gp):
            x, _, _ = self._rec_block(gp["rec1"], x)
            x = self._mlp_block(gp["mlp1"], x)
            x, _, _ = self._rec_block(gp["rec2"], x)
            x = self._mlp_block(gp["mlp2"], x)
            x, _ = self._attn_block(gp["attn"], x, positions)
            x = self._mlp_block(gp["mlp3"], x)
            return x, None

        fn = group_fn
        if cfg.remat == "layer":
            fn = jax.checkpoint(group_fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(fn, x, params["groups"])
        if self.tail:
            def tail_fn(x, tp):
                x, _, _ = self._rec_block(tp["rec"], x)
                x = self._mlp_block(tp["mlp"], x)
                return x, None
            x, _ = jax.lax.scan(tail_fn, x, params["tail"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
        return logits, jnp.zeros((), jnp.float32)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        w = cfg.rnn_width
        dh = cfg.resolved_head_dim
        win = min(cfg.local_window, max_seq)
        g, t = self.groups, self.tail
        cache = {
            "g_conv": jnp.zeros((g, 2, batch, 3, w), jnp.bfloat16),
            "g_h": jnp.zeros((g, 2, batch, w), jnp.float32),
            "g_k": jnp.zeros((g, batch, win, cfg.num_kv_heads, dh), jnp.bfloat16),
            "g_v": jnp.zeros((g, batch, win, cfg.num_kv_heads, dh), jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }  # ring window is small (2048): kv replication is cheap
        if t:
            cache["t_conv"] = jnp.zeros((t, batch, 3, w), jnp.bfloat16)
            cache["t_h"] = jnp.zeros((t, batch, w), jnp.float32)
        return cache

    def cache_axes(self):
        axes = {"g_conv": (None, None, "batch", None, "mlp"),
                "g_h": (None, None, "batch", "mlp"),
                "g_k": (None, "batch", "cache_seq", "kv_heads", None),
                "g_v": (None, "batch", "cache_seq", "kv_heads", None),
                "length": ()}
        if self.tail:
            axes["t_conv"] = (None, "batch", None, "mlp")
            axes["t_h"] = (None, "batch", "mlp")
        return axes

    def prefill(self, params: Params, tokens, max_seq: int):
        cfg = self.cfg
        b, s = tokens.shape
        win = min(cfg.local_window, max_seq)
        x = embed_lookup(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def group_fn(x, gp):
            x, c1, h1 = self._rec_block(gp["rec1"], x)
            x = self._mlp_block(gp["mlp1"], x)
            x, c2, h2 = self._rec_block(gp["rec2"], x)
            x = self._mlp_block(gp["mlp2"], x)
            x, (k, v) = self._attn_block(gp["attn"], x, positions)
            x = self._mlp_block(gp["mlp3"], x)
            # ring-buffer the last `win` keys at slot pos % win
            kpad = jnp.zeros((b, win, cfg.num_kv_heads,
                              cfg.resolved_head_dim), jnp.bfloat16)
            vpad = jnp.zeros_like(kpad)
            take = min(win, s)
            src = jnp.arange(s - take, s)
            slots = src % win
            kpad = kpad.at[:, slots].set(k[:, src].astype(jnp.bfloat16))
            vpad = vpad.at[:, slots].set(v[:, src].astype(jnp.bfloat16))
            conv = jnp.stack([c1, c2]).astype(jnp.bfloat16)
            hst = jnp.stack([h1.astype(jnp.float32), h2.astype(jnp.float32)])
            return x, (conv, hst, kpad, vpad)

        x, (convs, hs, ks, vs) = jax.lax.scan(group_fn, x, params["groups"])
        cache = {"g_conv": convs, "g_h": hs, "g_k": ks, "g_v": vs,
                 "length": jnp.asarray(s, jnp.int32)}
        if self.tail:
            def tail_fn(x, tp):
                x, c, h = self._rec_block(tp["rec"], x)
                x = self._mlp_block(tp["mlp"], x)
                return x, (c.astype(jnp.bfloat16), h.astype(jnp.float32))
            x, (tc, th) = jax.lax.scan(tail_fn, x, params["tail"])
            cache["t_conv"], cache["t_h"] = tc, th
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            params["lm_head"].astype(x.dtype))
        return logits, cache

    def decode_step(self, params: Params, cache, tokens):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b = tokens.shape[0]
        pos = cache["length"]
        win = cache["g_k"].shape[2]
        x = embed_lookup(params["embed"], tokens)
        positions = jnp.full((b, 1), pos, jnp.int32)

        def group_fn(x, xs):
            gp, conv, hst, kc, vc = xs
            x, c1, h1 = self._rec_block(gp["rec1"], x, conv[0].astype(x.dtype),
                                        hst[0], single_step=True)
            x = self._mlp_block(gp["mlp1"], x)
            x, c2, h2 = self._rec_block(gp["rec2"], x, conv[1].astype(x.dtype),
                                        hst[1], single_step=True)
            x = self._mlp_block(gp["mlp2"], x)
            # ring-buffer attention
            h = rms_norm(x, gp["attn"]["norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", h, gp["attn"]["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dh->bsh", h, gp["attn"]["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dh->bsh", h, gp["attn"]["wv"].astype(h.dtype))
            q = rope(q.reshape(b, 1, cfg.num_heads, dh), positions,
                     cfg.rope_theta)
            k = rope(k.reshape(b, 1, cfg.num_kv_heads, dh), positions,
                     cfg.rope_theta)
            v = v.reshape(b, 1, cfg.num_kv_heads, dh)
            slot = pos % win
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(jnp.bfloat16), slot, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(jnp.bfloat16), slot, 1)
            valid = jnp.minimum(pos + 1, win)
            attn = decode_attention(q, kc, vc, valid, cfg.num_kv_heads)
            attn = attn.reshape(b, 1, cfg.num_heads * dh)
            x = x + jnp.einsum("bsh,hd->bsd", attn,
                               gp["attn"]["wo"].astype(h.dtype))
            x = self._mlp_block(gp["mlp3"], x)
            conv = jnp.stack([c1, c2]).astype(jnp.bfloat16)
            hst = jnp.stack([h1.astype(jnp.float32), h2.astype(jnp.float32)])
            return x, (conv, hst, kc, vc)

        x, (convs, hs, ks, vs) = jax.lax.scan(
            group_fn, x,
            (params["groups"], cache["g_conv"], cache["g_h"], cache["g_k"],
             cache["g_v"]))
        new_cache = {"g_conv": convs, "g_h": hs, "g_k": ks, "g_v": vs,
                     "length": pos + 1}
        if self.tail:
            def tail_fn(x, xs):
                tp, conv, h = xs
                x, c, hn = self._rec_block(tp["rec"], x, conv.astype(x.dtype),
                                           h, single_step=True)
                x = self._mlp_block(tp["mlp"], x)
                return x, (c.astype(jnp.bfloat16), hn.astype(jnp.float32))
            x, (tc, th) = jax.lax.scan(
                tail_fn, x, (params["tail"], cache["t_conv"], cache["t_h"]))
            new_cache["t_conv"], new_cache["t_h"] = tc, th
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            params["lm_head"].astype(x.dtype))
        return logits, new_cache
