#!/usr/bin/env python
"""repro.lint CLI — the static half of the repo's invariant gates.

Usage::

    python tools/repro_lint.py --check src/repro
    python tools/repro_lint.py --check src/repro --format json
    python tools/repro_lint.py --explain RL102
    python tools/repro_lint.py --write-baseline src/repro

``--check`` exits nonzero on any live finding (not suppressed inline, not
in the committed baseline), on any baseline problem (a stale entry that no
longer fires — baselines shrink monotonically — or an entry without a
reason), or on a quarantine violation (RL001: a ``# repro-lint: legacy``
module reachable from a facade/serve/bench entry point).

Rules: RL101 trace-purity, RL102 priority-provenance, RL103 timing,
RL104 obs-hygiene, RL105 options-aliasing, RL106 kernel-masking.
``--explain RLxxx`` prints each rule's full story, including the
historical bug it would have caught.

CI runs this as the ``lint-invariants`` step; ``tools/check_shape.py``
is the runtime half of the same invariant set (execution-shape gates on
golden workloads).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

DEFAULT_BASELINE = REPO_ROOT / "tools" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST-level determinism & execution-shape analyzer")
    ap.add_argument("--check", nargs="+", metavar="PATH",
                    help="lint these files/directories; exit nonzero on "
                         "any live finding or baseline problem")
    ap.add_argument("--explain", metavar="RLxxx",
                    help="print the full docs for one rule and exit")
    ap.add_argument("--write-baseline", nargs="+", metavar="PATH",
                    help="lint and (re)write the baseline with every "
                         "current live finding (reasons stubbed FILLME — "
                         "an unedited baseline cannot pass --check)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help=f"baseline file (default {DEFAULT_BASELINE})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--no-reachability", action="store_true",
                    help="skip the module-reachability report section")
    args = ap.parse_args(argv)

    from repro.lint import (
        Baseline,
        baseline_from_findings,
        check,
        get_rule,
    )

    if args.explain:
        try:
            rule = get_rule(args.explain.upper())
        except KeyError:
            known = ", ".join(sorted(
                r.code for r in __import__(
                    "repro.lint.rules", fromlist=["all_rules"]).all_rules()))
            print(f"unknown rule {args.explain!r}; known: {known}",
                  file=sys.stderr)
            return 2
        print(rule.explain.rstrip())
        return 0

    targets = args.check or args.write_baseline
    if not targets:
        ap.error("one of --check, --explain, --write-baseline is required")

    result = check(targets, baseline=args.baseline, repo_root=REPO_ROOT)

    if args.write_baseline:
        bl = baseline_from_findings(result.findings)
        # keep still-firing existing entries (and their curated reasons)
        old = Baseline.load(args.baseline)
        live_keys = {e.key for e in bl.entries}
        merged = {e.key: e for e in bl.entries}
        for f, entry in result.grandfathered:
            merged[entry.key] = entry
        bl.entries = [merged[k] for k in sorted(merged)]
        bl.save(args.baseline)
        kept = sum(1 for e in bl.entries if e.reason != "FILLME")
        print(f"wrote {args.baseline}: {len(bl.entries)} entries "
              f"({kept} with curated reasons, "
              f"{len(bl.entries) - kept} FILLME stubs to edit)")
        del old
        return 0

    if args.format == "json":
        print(json.dumps(result.to_dict(), indent=2))
        return 0 if result.ok else 1

    # ---- text report ----------------------------------------------------
    for f in result.findings:
        print(f.render())
    for f, entry in result.grandfathered:
        print(f"{f.render()}  [baseline: {entry.reason}]")
    for msg in result.baseline_problems:
        print(f"BASELINE: {msg}")
    if result.legacy:
        print(f"-- {len(result.legacy)} finding(s) in legacy-quarantined "
              "modules (non-fatal):")
        for f in result.legacy:
            print(f"   {f.render()}")
    if not args.no_reachability:
        print("-- reachability (entry roots: repro.api / repro.serve / "
              "repro.obs / benchmarks / examples / tools / runnable "
              "__main__ modules):")
        print(f"   quarantined legacy modules: "
              f"{len(result.quarantined)}")
        for m in sorted(result.quarantined):
            print(f"     legacy      {m}")
        for m in sorted(result.test_only):
            print(f"     test-only   {m}  (parity/reference surface, "
                  "consumed by tests only)")
        for m in sorted(result.unreachable):
            print(f"     unreachable {m}  (no legacy tag — retire or wire "
                  "it up)")
    n_sup = len(result.suppressed)
    n_bl = len(result.grandfathered)
    verdict = "clean" if result.ok else "FAILED"
    print(f"repro-lint: {verdict} — {len(result.findings)} live finding(s), "
          f"{n_bl} baselined, {n_sup} suppressed inline, "
          f"{len(result.legacy)} legacy")
    return 0 if result.ok else 1


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:  # e.g. `--explain RLxxx | head`
        sys.exit(0)
