#!/usr/bin/env python
"""Execution-shape regression gates over the ``repro.obs`` registry.

Each gate runs a small workload under ``obs.capture()`` and asserts the
*shape* of the execution — the counters the whole optimization story hangs
on — by diffing registry snapshots:

* ``resident``   the device-resident MIS-2 hot loop is exactly ONE jitted
  dispatch with ZERO in-loop host syncs (PR 4's contract).
* ``serve``      a warmed server keeps the request path compile-free:
  dispatching distinct graphs in a configured bucket shape performs ZERO
  runtime compiles (PR 6's contract).
* ``dist``       the sharded engine's collective traffic matches the §V-C
  analytic model byte-for-byte: the registry delta equals
  ``collective_bytes_per_iteration(V, P) x iterations`` and the result's
  own ``collectives`` accounting.

Usage::

    PYTHONPATH=src python tools/check_shape.py [--gates resident,serve,dist]

Prints one PASS/FAIL line per gate; exits nonzero if any gate fails.
CI runs this in the test lane (the ``obs-gates`` step).
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


class GateFailure(AssertionError):
    pass


def _expect(cond: bool, msg: str) -> None:
    if not cond:
        raise GateFailure(msg)


# ---------------------------------------------------------------------------
# gate: resident — 1 dispatch, 0 host syncs per solve
# ---------------------------------------------------------------------------

def gate_resident() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import random_uniform_graph

    g = repro.Graph(random_uniform_graph(4000, 8.0, seed=7))
    repro.mis2(g, engine="compacted_resident")      # warm the jit cache
    with obs.capture() as cap:
        r = repro.mis2(g, engine="compacted_resident")
    _expect(r.iterations > 1, "workload too easy: need a multi-round solve")
    dispatches = cap.value("mis2.resident_dispatches")
    syncs = cap.value("mis2.host_syncs")
    _expect(dispatches == 1,
            f"resident solve took {dispatches} dispatches, want exactly 1")
    _expect(syncs == 0,
            f"resident solve paid {syncs} in-loop host syncs, want 0")
    return (f"1 dispatch, 0 host syncs across {r.iterations} rounds "
            f"(engine={r.engine})")


# ---------------------------------------------------------------------------
# gate: serve — warmed buckets keep the request path compile-free
# ---------------------------------------------------------------------------

def gate_serve() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import random_uniform_graph
    from repro.serve import Server, ServerConfig, warm_buckets_for

    graphs = [repro.Graph(random_uniform_graph(600, 6.0, seed=s))
              for s in range(4)]
    config = ServerConfig(max_batch=4, max_delay_s=0.0,
                          warm_buckets=warm_buckets_for(graphs),
                          single_fast_path=False)
    server = Server(config)
    try:
        with obs.capture() as cap:
            futures = [server.submit("mis2", g) for g in graphs]
            server.flush()
            results = [f.result(timeout=120) for f in futures]
        _expect(all(r.converged for r in results), "serve results diverged")
        compiles = cap.value("serve.warm.runtime_compiles")
        dispatches = cap.value("serve.dispatches")
        _expect(dispatches >= 1, "server never dispatched")
        _expect(compiles == 0,
                f"warm request path paid {compiles} runtime compiles, want 0")
    finally:
        server.stop()
    return (f"{len(graphs)} graphs through warmed buckets: "
            f"0 request-path compiles ({int(dispatches)} dispatches)")


# ---------------------------------------------------------------------------
# gate: dist — registry collective bytes == analytic model == result record
# ---------------------------------------------------------------------------

def gate_dist() -> str:
    import jax

    import repro
    from repro import obs
    from repro.core.dist import collective_bytes_per_iteration
    from repro.graphs.generators import random_uniform_graph

    devices = jax.devices()
    v = 2048
    g = repro.Graph(random_uniform_graph(v, 8.0, seed=11))
    with obs.capture() as cap:
        r = repro.mis2(g, engine="distributed")
    variant = r.collectives["variant"]
    got = cap.value("dist.collective_bytes", {"variant": variant})
    per = collective_bytes_per_iteration(v, len(devices),
                                         variant == "single_gather")
    want = per["result_bytes_per_iteration"] * r.iterations
    _expect(got == want,
            f"registry recorded {got} collective bytes, analytic model says "
            f"{want} ({variant}, {len(devices)} devices, "
            f"{r.iterations} iterations)")
    _expect(got == r.collectives["result_bytes_total"],
            f"registry ({got}) disagrees with the result's own accounting "
            f"({r.collectives['result_bytes_total']})")
    return (f"{int(got)} bytes == analytic model == result record "
            f"({variant}, {len(devices)} devices, {r.iterations} iters)")


GATES = {
    "resident": gate_resident,
    "serve": gate_serve,
    "dist": gate_dist,
}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gates", default=",".join(GATES),
                    help="comma-separated subset of " + ",".join(GATES))
    args = ap.parse_args()
    names = [n.strip() for n in args.gates.split(",") if n.strip()]
    unknown = [n for n in names if n not in GATES]
    if unknown:
        print(f"unknown gate(s): {', '.join(unknown)}", file=sys.stderr)
        return 2
    failed = 0
    for name in names:
        try:
            detail = GATES[name]()
        except GateFailure as e:
            print(f"FAIL  {name:<9} {e}")
            failed += 1
        except Exception:
            print(f"FAIL  {name:<9} crashed:")
            traceback.print_exc()
            failed += 1
        else:
            print(f"PASS  {name:<9} {detail}")
    if failed:
        print(f"{failed}/{len(names)} execution-shape gates failed")
        return 1
    print(f"all {len(names)} execution-shape gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
