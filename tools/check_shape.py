#!/usr/bin/env python
"""Execution-shape regression gates over the ``repro.obs`` registry.

Each gate runs a small workload under ``obs.capture()`` and asserts the
*shape* of the execution — the counters the whole optimization story hangs
on — by diffing registry snapshots:

* ``resident``   the device-resident MIS-2 hot loop is exactly ONE jitted
  dispatch with ZERO in-loop host syncs (PR 4's contract).
* ``serve``      a warmed server keeps the request path compile-free:
  dispatching distinct graphs in a configured bucket shape performs ZERO
  runtime compiles (PR 6's contract).
* ``serve_dedup``  N concurrent same-digest requests coalesce to exactly
  ONE compute, and a fault-degraded server still serves the referent
  digest with ZERO request-path compiles (the hardening contract).
* ``dist``       the sharded engine's collective traffic matches the §V-C
  analytic model byte-for-byte: the registry delta equals
  ``collective_bytes_per_iteration(V, P) x iterations`` and the result's
  own ``collectives`` accounting.

Usage::

    PYTHONPATH=src python tools/check_shape.py [--gates resident,serve,dist]

Prints one PASS/FAIL line per gate; exits nonzero if any gate fails.
CI runs this in the test lane (the ``obs-gates`` step).
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


class GateFailure(AssertionError):
    pass


def _expect(cond: bool, msg: str) -> None:
    if not cond:
        raise GateFailure(msg)


# ---------------------------------------------------------------------------
# gate: resident — 1 dispatch, 0 host syncs per solve
# ---------------------------------------------------------------------------

def gate_resident() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import random_uniform_graph

    g = repro.Graph(random_uniform_graph(4000, 8.0, seed=7))
    repro.mis2(g, engine="compacted_resident")      # warm the jit cache
    with obs.capture() as cap:
        r = repro.mis2(g, engine="compacted_resident")
    _expect(r.iterations > 1, "workload too easy: need a multi-round solve")
    dispatches = cap.value("mis2.resident_dispatches")
    syncs = cap.value("mis2.host_syncs")
    _expect(dispatches == 1,
            f"resident solve took {dispatches} dispatches, want exactly 1")
    _expect(syncs == 0,
            f"resident solve paid {syncs} in-loop host syncs, want 0")
    return (f"1 dispatch, 0 host syncs across {r.iterations} rounds "
            f"(engine={r.engine})")


# ---------------------------------------------------------------------------
# gate: serve — warmed buckets keep the request path compile-free
# ---------------------------------------------------------------------------

def gate_serve() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import random_uniform_graph
    from repro.serve import Server, ServerConfig, warm_buckets_for

    graphs = [repro.Graph(random_uniform_graph(600, 6.0, seed=s))
              for s in range(4)]
    config = ServerConfig(max_batch=4, max_delay_s=0.0,
                          warm_buckets=warm_buckets_for(graphs),
                          single_fast_path=False)
    server = Server(config)
    try:
        with obs.capture() as cap:
            futures = [server.submit("mis2", g) for g in graphs]
            server.flush()
            results = [f.result(timeout=120) for f in futures]
        _expect(all(r.converged for r in results), "serve results diverged")
        compiles = cap.value("serve.warm.runtime_compiles")
        dispatches = cap.value("serve.dispatches")
        _expect(dispatches >= 1, "server never dispatched")
        _expect(compiles == 0,
                f"warm request path paid {compiles} runtime compiles, want 0")
    finally:
        server.stop()
    return (f"{len(graphs)} graphs through warmed buckets: "
            f"0 request-path compiles ({int(dispatches)} dispatches)")


# ---------------------------------------------------------------------------
# gate: serve_dedup — N concurrent same-digest requests: exactly 1 compute,
# and a degraded (fault-injected) server keeps the request path compile-free
# ---------------------------------------------------------------------------

def gate_serve_dedup() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import random_uniform_graph
    from repro.serve import (Fault, FaultPlan, RetryPolicy, Server,
                             ServerConfig, warm_buckets_for)

    n = 8
    base = repro.Graph(random_uniform_graph(600, 6.0, seed=3))
    clones = [repro.Graph(base.csr) for _ in range(n)]     # digest-equal
    warm = warm_buckets_for([base])

    # --- phase 1: N concurrent same-digest requests -> exactly 1 compute
    server = Server(ServerConfig(max_batch=n, max_delay_s=0.0,
                                 warm_buckets=warm, single_fast_path=False))
    try:
        with obs.capture() as cap:
            futures = [server.submit("mis2", g) for g in clones]
            server.flush()
            results = [f.result(timeout=120) for f in futures]
        digests = {r.digest for r in results}
        _expect(len(digests) == 1,
                f"same-key requests returned {len(digests)} digests, want 1")
        dedup_hits = cap.value("serve.dedup_hits")
        computes = (cap.value("serve.single_dispatches")
                    + cap.value("serve.batched_graphs"))
        compiles = cap.value("serve.warm.runtime_compiles")
        _expect(dedup_hits == n - 1,
                f"{n} same-digest submits coalesced {dedup_hits} joins, "
                f"want {n - 1}")
        _expect(computes == 1,
                f"{n} same-digest requests cost {computes} computes, want "
                "exactly 1")
        _expect(compiles == 0,
                f"dedup path paid {compiles} runtime compiles, want 0")
    finally:
        server.stop()

    # --- phase 2: degraded server (seeded transient engine fault, retried)
    # still serves the correct digest with 0 request-path compiles
    referent = repro.mis2(base, engine="dense")     # warm referent programs
    plan = FaultPlan(seed=5, sites={
        "engine": Fault("error", count=1, transient=True)})
    server = Server(ServerConfig(max_batch=n, max_delay_s=0.0,
                                 warm_buckets=warm, single_fast_path=False,
                                 faults=plan,
                                 retry=RetryPolicy(base_backoff_s=0.0)))
    try:
        with obs.capture() as cap:
            fut = server.submit("mis2", base)
            server.flush()
            degraded = fut.result(timeout=120)
        _expect(degraded.digest == referent.digest,
                f"degraded response digest {degraded.digest} != referent "
                f"{referent.digest}")
        retries = cap.value("serve.retries", {"site": "engine"})
        compiles = cap.value("serve.warm.runtime_compiles")
        _expect(retries == 1,
                f"transient fault provoked {retries} retries, want 1")
        _expect(compiles == 0,
                f"degraded request path paid {compiles} runtime compiles, "
                "want 0")
    finally:
        server.stop()
    return (f"{n} same-digest requests -> 1 compute ({int(dedup_hits)} "
            f"joins); degraded serve digest-correct after {int(retries)} "
            "retry, 0 compiles")


# ---------------------------------------------------------------------------
# gate: dist — registry collective bytes == analytic model == result record
# ---------------------------------------------------------------------------

def gate_dist() -> str:
    import jax

    import repro
    from repro import obs
    from repro.core.dist import collective_bytes_per_iteration
    from repro.graphs.generators import random_uniform_graph

    devices = jax.devices()
    v = 2048
    g = repro.Graph(random_uniform_graph(v, 8.0, seed=11))
    with obs.capture() as cap:
        r = repro.mis2(g, engine="distributed")
    variant = r.collectives["variant"]
    got = cap.value("dist.collective_bytes", {"variant": variant})
    per = collective_bytes_per_iteration(v, len(devices),
                                         variant == "single_gather")
    want = per["result_bytes_per_iteration"] * r.iterations
    _expect(got == want,
            f"registry recorded {got} collective bytes, analytic model says "
            f"{want} ({variant}, {len(devices)} devices, "
            f"{r.iterations} iterations)")
    _expect(got == r.collectives["result_bytes_total"],
            f"registry ({got}) disagrees with the result's own accounting "
            f"({r.collectives['result_bytes_total']})")
    return (f"{int(got)} bytes == analytic model == result record "
            f"({variant}, {len(devices)} devices, {r.iterations} iters)")


# ---------------------------------------------------------------------------
# gate: hybrid_traffic — registry row-traffic bytes == analytic model ==
# result record, and the hybrid solve is one resident dispatch
# ---------------------------------------------------------------------------

def gate_hybrid_traffic() -> str:
    import repro
    from repro import obs
    from repro.graphs.generators import powerlaw_graph
    from repro.kernels.minprop_ell.ops import hybrid_row_traffic_bytes

    g = repro.Graph(powerlaw_graph(4000, 8.0, seed=7))
    repro.mis2(g, engine="pallas_hybrid")           # warm the jit cache
    with obs.capture() as cap:
        r = repro.mis2(g, engine="pallas_hybrid")
    _expect(r.iterations > 1, "workload too easy: need a multi-round solve")
    c = r.collectives
    _expect(c["variant"] == "hybrid", f"unexpected variant {c['variant']!r}")
    got = cap.value("mis2.hybrid_row_bytes")
    want = hybrid_row_traffic_bytes(c["slice_widths"],
                                    c["slice_rows_processed"],
                                    c["spill_entries"], c["spill_passes"])
    _expect(got == want,
            f"registry recorded {got} hybrid row bytes, analytic model says "
            f"{want} (widths={c['slice_widths']}, "
            f"spill_entries={c['spill_entries']})")
    _expect(got == c["row_bytes_total"],
            f"registry ({got}) disagrees with the result's own accounting "
            f"({c['row_bytes_total']})")
    dispatches = cap.value("mis2.resident_dispatches")
    syncs = cap.value("mis2.host_syncs")
    _expect(dispatches == 1,
            f"hybrid solve took {dispatches} dispatches, want exactly 1")
    _expect(syncs == 0,
            f"hybrid solve paid {syncs} in-loop host syncs, want 0")
    return (f"{int(got)} bytes == analytic model == result record "
            f"({len(c['slice_widths'])} slices + {c['spill_entries']} spill "
            f"entries, {r.iterations} iters, 1 dispatch)")


GATES = {
    "resident": gate_resident,
    "serve": gate_serve,
    "serve_dedup": gate_serve_dedup,
    "dist": gate_dist,
    "hybrid_traffic": gate_hybrid_traffic,
}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gates", default=",".join(GATES),
                    help="comma-separated subset of " + ",".join(GATES))
    args = ap.parse_args()
    names = [n.strip() for n in args.gates.split(",") if n.strip()]
    unknown = [n for n in names if n not in GATES]
    if unknown:
        print(f"unknown gate(s): {', '.join(unknown)}", file=sys.stderr)
        return 2
    failed = 0
    for name in names:
        try:
            detail = GATES[name]()
        except GateFailure as e:
            print(f"FAIL  {name:<9} {e}")
            failed += 1
        except Exception:
            print(f"FAIL  {name:<9} crashed:")
            traceback.print_exc()
            failed += 1
        else:
            print(f"PASS  {name:<9} {detail}")
    if failed:
        print(f"{failed}/{len(names)} execution-shape gates failed")
        return 1
    print(f"all {len(names)} execution-shape gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
