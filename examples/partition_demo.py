"""Device placement via MIS-2 multilevel partitioning (DESIGN.md
§Arch-applicability): coarsen an operator/communication graph with
Algorithm 3 and split it over devices.

Two demos:
1. a 2D mesh operator graph split over 16 devices;
2. an MoE expert co-activation graph clustered into expert-parallel groups.

    PYTHONPATH=src python examples/partition_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.api import Graph, partition  # noqa: E402
from repro.graphs import csr_from_coo, laplace3d  # noqa: E402


def expert_coactivation_graph(num_experts=60, seed=0):
    """Synthetic expert co-activation counts (top-k routing correlations)."""
    rng = np.random.default_rng(seed)
    # block-structured affinity: experts cluster into latent groups
    groups = rng.integers(0, 8, size=num_experts)
    rows, cols = [], []
    for i in range(num_experts):
        for j in range(num_experts):
            if i != j:
                p = 0.45 if groups[i] == groups[j] else 0.04
                if rng.random() < p:
                    rows.append(i)
                    cols.append(j)
    rows, cols = np.array(rows), np.array(cols)
    all_r = np.concatenate([rows, cols, np.arange(num_experts)])
    all_c = np.concatenate([cols, rows, np.arange(num_experts)])
    return csr_from_coo(all_r, all_c, num_experts)


def main():
    # 1. operator graph over devices
    g = Graph(laplace3d(24).graph)
    res = partition(g, 16)
    sizes = np.bincount(res.parts, minlength=16)
    print(f"mesh operator graph: V={g.num_vertices} -> 16 devices, "
          f"edge cut {res.edge_cut} "
          f"({100 * res.edge_cut / (g.num_entries // 2):.1f}% of edges), "
          f"load balance {sizes.max() / sizes.mean():.2f}")

    # 2. MoE expert clusters (qwen2-moe has 60 routed experts)
    eg = Graph(expert_coactivation_graph(60))
    res = partition(eg, 4, coarse_target=16)
    print(f"expert co-activation graph: 60 experts -> 4 EP groups, "
          f"cut {res.edge_cut}, groups "
          f"{np.bincount(res.parts, minlength=4).tolist()}")

    # determinism (the paper's headline property, preserved end to end)
    res2 = partition(eg, 4, coarse_target=16)
    assert (res.parts == res2.parts).all()
    print("placement is deterministic across runs")


if __name__ == "__main__":
    main()
