"""Quickstart: MIS-2 + two-phase aggregation on a generated mesh problem.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import Mis2Options, aggregate_two_phase, mis2  # noqa: E402
from repro.graphs import laplace3d  # noqa: E402


def main():
    # the paper's Laplace3D generator (7-point stencil)
    matrix = laplace3d(32)
    graph = matrix.graph
    print(f"graph: V={graph.num_vertices} E={graph.num_entries}")

    # distance-2 maximal independent set (Algorithm 1, all optimizations)
    result = mis2(graph, options=Mis2Options(priority="xorshift_star"))
    print(f"MIS-2: size={result.size} "
          f"({100 * result.size / graph.num_vertices:.1f}% of V), "
          f"iterations={result.iterations}")

    # deterministic: identical on every run / device count
    again = mis2(graph)
    assert (again.in_set == result.in_set).all()
    print("deterministic: re-run produced the identical set")

    # two-phase MIS-2 aggregation (Algorithm 3)
    agg = aggregate_two_phase(graph)
    sizes = np.bincount(agg.labels)
    print(f"aggregation: {agg.num_aggregates} aggregates, "
          f"coarsening ratio {agg.coarsening_ratio:.1f}, "
          f"sizes min/mean/max = {sizes.min()}/{sizes.mean():.1f}/{sizes.max()}")


if __name__ == "__main__":
    main()
