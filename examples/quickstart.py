"""Quickstart: the `repro.api` facade on a generated mesh problem.

    PYTHONPATH=src python examples/quickstart.py [grid_size]

``grid_size`` (default 32) is the Laplace3D mesh edge; CI smoke passes a
small value so this example stays cheap enough to run on every push.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.api import (  # noqa: E402
    Graph,
    GraphBatch,
    Mis2Options,
    coarsen,
    list_engines,
    mis2,
    mis2_batch,
)
from repro.api.generators import laplace3d, random_uniform_graph  # noqa: E402


def main(n: int = 32):
    # the paper's Laplace3D generator (7-point stencil), wrapped in the
    # cached-format handle: ELL/CSR conversions happen once, on first use
    graph = Graph(laplace3d(n))
    print(f"graph: V={graph.num_vertices} E={graph.num_entries}")

    # distance-2 maximal independent set (Algorithm 1, all optimizations)
    result = mis2(graph, options=Mis2Options(priority="xorshift_star"))
    print(f"MIS-2: size={result.size} "
          f"({100 * result.size / graph.num_vertices:.1f}% of V), "
          f"iterations={result.iterations}, "
          f"wall={result.wall_time_s * 1e3:.1f}ms")

    # portable: every engine returns the bit-identical set — one digest
    for engine in list_engines("mis2")["mis2"]:
        again = mis2(graph, engine=engine)
        assert again.digest == result.digest, engine
    print(f"deterministic: engines {list_engines('mis2')['mis2']} all "
          f"produced digest {result.digest}")
    print(f"format cache: {graph.conversions} (ELL built once, reused "
          f"by every engine)")

    # two-phase MIS-2 aggregation (Algorithm 3)
    agg = coarsen(graph, method="two_phase")
    sizes = np.bincount(agg.labels)
    print(f"aggregation: {agg.num_aggregates} aggregates, "
          f"coarsening ratio {agg.coarsening_ratio:.1f}, "
          f"sizes min/mean/max = {sizes.min()}/{sizes.mean():.1f}/{sizes.max()}")

    # batched: a fleet of graphs, bucketed by shape, one vmapped dispatch
    # per bucket — per-graph digests bit-identical to the dense engine
    fleet = [Graph(laplace3d(max(2, n // 4)).graph),
             Graph(laplace3d(max(2, n // 8)).graph),
             Graph(random_uniform_graph(10 * n, 5.0, seed=1)),
             Graph(random_uniform_graph(20 * n, 6.0, seed=2))]
    batch = GraphBatch(fleet)
    br = mis2_batch(batch)
    for g, r in zip(fleet, br):
        assert r.digest == mis2(g, engine="dense").digest
    print(f"batched MIS-2: {len(br)} graphs in {br.num_buckets} buckets "
          f"{batch.bucket_shapes}, {br.graphs_per_second:.0f} graphs/sec, "
          f"digests match the dense engine")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 32)
