"""Quickstart: the `repro.api` facade on a generated mesh problem.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.api import Graph, Mis2Options, coarsen, list_engines, mis2  # noqa: E402
from repro.api.generators import laplace3d  # noqa: E402


def main():
    # the paper's Laplace3D generator (7-point stencil), wrapped in the
    # cached-format handle: ELL/CSR conversions happen once, on first use
    graph = Graph(laplace3d(32))
    print(f"graph: V={graph.num_vertices} E={graph.num_entries}")

    # distance-2 maximal independent set (Algorithm 1, all optimizations)
    result = mis2(graph, options=Mis2Options(priority="xorshift_star"))
    print(f"MIS-2: size={result.size} "
          f"({100 * result.size / graph.num_vertices:.1f}% of V), "
          f"iterations={result.iterations}, "
          f"wall={result.wall_time_s * 1e3:.1f}ms")

    # portable: every engine returns the bit-identical set — one digest
    for engine in list_engines("mis2")["mis2"]:
        again = mis2(graph, engine=engine)
        assert again.digest == result.digest, engine
    print(f"deterministic: engines {list_engines('mis2')['mis2']} all "
          f"produced digest {result.digest}")
    print(f"format cache: {graph.conversions} (ELL built once, reused "
          f"by every engine)")

    # two-phase MIS-2 aggregation (Algorithm 3)
    agg = coarsen(graph, method="two_phase")
    sizes = np.bincount(agg.labels)
    print(f"aggregation: {agg.num_aggregates} aggregates, "
          f"coarsening ratio {agg.coarsening_ratio:.1f}, "
          f"sizes min/mean/max = {sizes.min()}/{sizes.mean():.1f}/{sizes.max()}")


if __name__ == "__main__":
    main()
