"""Cluster multicolor Gauss-Seidel (paper Alg. 4) vs point multicolor GS as
GMRES preconditioners — the paper's Table VI setting.

    PYTHONPATH=src python examples/cluster_gs_precond.py [--n 16]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import Graph  # noqa: E402
from repro.graphs import laplace3d  # noqa: E402
from repro.graphs.ops import spmv_ell  # noqa: E402
from repro.solvers import gmres, setup_cluster_gs, setup_point_gs  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    args = ap.parse_args()

    a = Graph(laplace3d(args.n))
    ell = a.ell_matrix
    b = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(a.num_vertices).astype(np.float32))
    mv = lambda x: spmv_ell(ell, x)  # noqa: E731
    print(f"Laplace3D {args.n}^3: V={a.num_vertices}")

    for kind, setup in (("point", setup_point_gs),
                        ("cluster", setup_cluster_gs)):
        pre = setup(a)
        t0 = time.time()
        res = gmres(mv, b, precond=pre.as_precond(sweeps=1, symmetric=True),
                    tol=1e-6, maxiter=800)
        apply_s = time.time() - t0
        print(f"{kind:8s} SGS: setup {pre.setup_seconds:.2f}s "
              f"({pre.num_colors} colors over {pre.num_clusters} clusters), "
              f"GMRES {res.iterations} iters in {apply_s:.2f}s, "
              f"converged={res.converged}")


if __name__ == "__main__":
    main()
