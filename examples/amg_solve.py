"""End-to-end driver: solve a 3D Poisson system with SA-AMG-preconditioned
CG, comparing the paper's aggregation schemes (Table V setting).

    PYTHONPATH=src python examples/amg_solve.py [--n 32] [--tol 1e-10]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import Graph, amg  # noqa: E402
from repro.graphs import laplace3d  # noqa: E402
from repro.graphs.ops import spmv_ell  # noqa: E402
from repro.solvers import cg  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--tol", type=float, default=1e-10)
    args = ap.parse_args()

    a = Graph(laplace3d(args.n))
    ell = a.ell_matrix
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.num_vertices).astype(np.float32))
    mv = lambda x: spmv_ell(ell, x)  # noqa: E731
    print(f"Laplace3D {args.n}^3: V={a.num_vertices} nnz={a.num_entries}")

    plain = cg(mv, b, tol=args.tol, maxiter=3000)
    print(f"plain CG:        {plain.iterations} iterations")

    for agg in ("serial", "basic", "two_phase"):
        h = amg(a, aggregation=agg)
        t0 = time.time()
        res = cg(mv, b, precond=h.as_precond(), tol=args.tol, maxiter=300)
        solve_s = time.time() - t0
        levels = " -> ".join(str(v) for v, _ in h.level_sizes)
        print(f"AMG[{agg:10s}]: {res.iterations:3d} iterations "
              f"(setup {h.wall_time_s:.2f}s of which aggregation "
              f"{h.aggregation_seconds:.2f}s, solve {solve_s:.2f}s) "
              f"levels {levels}")


if __name__ == "__main__":
    main()
