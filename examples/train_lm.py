"""Train an LM end to end on the synthetic pipeline (assignment deliverable
(b): train a ~100M model for a few hundred steps).

Presets:
  tiny  — 2-layer reduced config, runs in ~1 min on this CPU (CI default)
  100m  — smollm-135m at full width, short sequence; a few hundred steps
          (several hours on 1 CPU core; the real target is the TPU mesh)

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20"]
    if args.preset == "tiny":
        argv += ["--reduced", "--batch", "8", "--seq", "128"]
    else:
        argv += ["--batch", "8", "--seq", "512"]
    train_main(argv)


if __name__ == "__main__":
    main()
